"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.causal import blockwise_causal_attention
from repro.kernels import ops, ref

SHAPES = [  # (B, H, Hkv, S, Dh, K)
    (1, 2, 2, 64, 16, 8),
    (2, 4, 2, 128, 32, 16),
    (1, 8, 4, 256, 64, 32),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_linformer_attn_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    kbar = jax.random.normal(ks[1], (B, K, Hkv, Dh), dtype)
    vbar = jax.random.normal(ks[2], (B, K, Hkv, Dh), dtype)
    scale = Dh ** -0.5
    out = ops.fused_linformer_attention(q, kbar, vbar, scale=scale,
                                        block_q=min(64, S))
    qk = jnp.moveaxis(q, 2, 1)
    kb = jnp.repeat(jnp.moveaxis(kbar, 2, 1), H // Hkv, 1)
    vb = jnp.repeat(jnp.moveaxis(vbar, 2, 1), H // Hkv, 1)
    expect = jnp.moveaxis(ref.linformer_attn_ref(qk, kb, vb, scale), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_seq_projection_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, Dh), dtype)
    E = (jax.random.normal(jax.random.PRNGKey(2), (S, K)) * 0.2).astype(dtype)
    out = ops.fused_seq_projection(x, E, block_s=min(64, S))
    expect = jnp.moveaxis(
        ref.seq_projection_ref(jnp.moveaxis(x, 2, 1), E), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_blockwise_causal_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    c, r = 32, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    E = jax.random.normal(ks[3], (c, r)) * 0.3
    F = jax.random.normal(ks[4], (c, r)) * 0.3
    scale = Dh ** -0.5
    out = ops.fused_blockwise_causal_attention(
        q, k, v, E, F, block_size=c, block_slots=r, scale=scale)
    expect = blockwise_causal_attention(q, k, v, E, F, block_size=c,
                                        scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=5e-5, rtol=5e-5)


def test_seq_projection_accumulator_matches_single_block():
    """Multi-block accumulation must equal one big block (fp32 accumulate)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32), jnp.float32)
    E = jax.random.normal(jax.random.PRNGKey(2), (256, 16)) * 0.2
    a = ops.fused_seq_projection(x, E, block_s=32)
    b = ops.fused_seq_projection(x, E, block_s=256)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_linformer_attn_custom_vjp_matches_autodiff():
    """The fused kernel is trainable: its analytic VJP equals autodiff of
    the pure-jnp reference (including the GQA head-repeat fold)."""
    from repro.core.linformer import attend_compressed
    B, H, Hkv, S, Dh, K = 1, 4, 2, 64, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    kb = jax.random.normal(ks[1], (B, K, Hkv, Dh))
    vb = jax.random.normal(ks[2], (B, K, Hkv, Dh))
    scale = Dh ** -0.5

    def via_kernel(q, kb, vb):
        return jnp.sum(ops.fused_linformer_attention(
            q, kb, vb, scale=scale, block_q=32) ** 2)

    def via_ref(q, kb, vb):
        return jnp.sum(attend_compressed(q, kb, vb, scale=scale) ** 2)

    g1 = jax.grad(via_kernel, argnums=(0, 1, 2))(q, kb, vb)
    g2 = jax.grad(via_ref, argnums=(0, 1, 2))(q, kb, vb)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


def test_linformer_attn_rows_sum_to_one_property():
    """Kernel softmax: uniform values -> output equals that value."""
    B, H, S, Dh, K = 1, 2, 64, 16, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh))
    kbar = jax.random.normal(jax.random.PRNGKey(1), (B, K, H, Dh))
    vbar = jnp.full((B, K, H, Dh), 0.731)
    out = ops.fused_linformer_attention(q, kbar, vbar, scale=0.25,
                                        block_q=32)
    np.testing.assert_allclose(out, jnp.full_like(out, 0.731), atol=1e-5)


# ---------------------------------------------------------------------------
# Fail-fast wrapper validation (silent-degradation bugfixes)
# ---------------------------------------------------------------------------


def test_divisor_block_floor():
    """`_divisor_block` must refuse degenerate grids instead of silently
    shrinking to near-per-row blocks (S=509 prime used to mean a 509-step
    grid per (batch, head))."""
    assert ops._divisor_block(512, 256) == 256
    assert ops._divisor_block(96, 64) == 48
    # sizes below the floor are a single block, not degradation
    assert ops._divisor_block(4, 8) == 4
    # sub-floor blocks are fine while the grid stays small
    assert ops._divisor_block(12, 8) == 6
    for bad in (509, 523, 514):      # prime / prime / largest divisor 2
        with pytest.raises(ValueError, match=str(bad)):
            ops._divisor_block(bad, 256)


def test_exact_form_k_budget_fail_fast():
    """K > MAX_EXACT_K cannot pin in VMEM — must raise, not compile."""
    K = ops.MAX_EXACT_K + 8
    q = jnp.zeros((1, 16, 2, 4))
    kbar = jnp.zeros((1, K, 2, 4))
    with pytest.raises(ValueError, match=str(ops.MAX_EXACT_K)):
        ops.fused_linformer_attention(q, kbar, kbar, scale=0.5)
    # the documented budget itself is still accepted (shape check only)
    assert ops.MAX_EXACT_K == 512


def test_causal_form_slot_budget_fail_fast():
    """M = (S/c)·r > MAX_PINNED_SLOTS must raise in every causal-family
    wrapper (training, chunk prefill, decode)."""
    c, r = 8, 8
    S = ((ops.MAX_PINNED_SLOTS // r) + 1) * c          # M = MAX + r
    q = jnp.zeros((1, S, 2, 4))
    kv = jnp.zeros((1, S, 1, 4))
    E = jnp.zeros((c, r))
    with pytest.raises(ValueError, match=str(ops.MAX_PINNED_SLOTS)):
        ops.fused_blockwise_causal_attention(
            q, kv, kv, E, E, block_size=c, block_slots=r, scale=0.5)
    M = ops.MAX_PINNED_SLOTS + 8
    comp = jnp.zeros((1, M, 1, 4))
    with pytest.raises(ValueError, match=str(ops.MAX_PINNED_SLOTS)):
        ops.fused_chunk_prefill_attention(
            jnp.zeros((1, c, 2, 4)), jnp.zeros((1, c, 1, 4)),
            jnp.zeros((1, c, 1, 4)), comp, comp,
            jnp.zeros((1,), jnp.int32), block_size=c, block_slots=r,
            scale=0.5)
    with pytest.raises(ValueError, match=str(ops.MAX_PINNED_SLOTS)):
        ops.fused_decode_attention(
            jnp.zeros((1, 1, 2, 4)), jnp.zeros((1, c, 1, 4)),
            jnp.zeros((1, c, 1, 4)), comp, comp,
            jnp.zeros((1, c)), jnp.zeros((1, M)), scale=0.5)


def test_backward_impl_knob_validated():
    q = jnp.zeros((1, 16, 2, 4))
    kv = jnp.zeros((1, 16, 1, 4))
    E = jnp.zeros((8, 2))
    with pytest.raises(ValueError, match="backward_impl"):
        ops.fused_blockwise_causal_attention(
            q, kv, kv, E, E, block_size=8, block_slots=2, scale=0.5,
            backward_impl="autodiff")


# ---------------------------------------------------------------------------
# Fused blockwise-causal backward: gradient parity vs the reference VJP
# ---------------------------------------------------------------------------


def _bca_grad_case(B, H, Hkv, S, Dh, c, r, dtype, ef_shape, seed=7):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    E = jax.random.normal(ks[3], ef_shape) * 0.3
    F = jax.random.normal(ks[4], ef_shape) * 0.3
    do = jax.random.normal(ks[5], (B, S, H, Dh))
    return q, k, v, E, F, do


def _bca_grads(q, k, v, E, F, do, c, r, backward_impl):
    def loss(q_, k_, v_, E_, F_):
        out = ops.fused_blockwise_causal_attention(
            q_, k_, v_, E_, F_, block_size=c, block_slots=r,
            scale=q.shape[-1] ** -0.5, backward_impl=backward_impl)
        return jnp.sum(out.astype(jnp.float32) * do)
    return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, E, F)


def _assert_grads_close(got, want, dtype):
    for name, a, b in zip("qkvEF", got, want):
        b32 = np.asarray(b, np.float32)
        # atol scales with the gradient's magnitude: rtol alone trips on
        # near-zero entries, and long-S reductions accumulate rounding
        # proportional to the result's scale
        scale_ = max(1.0, float(np.max(np.abs(b32))))
        if dtype == jnp.bfloat16:
            tol = dict(atol=5e-2 * scale_, rtol=5e-2)
        else:
            tol = dict(atol=2e-5 * scale_, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(a, np.float32), b32,
                                   err_msg=f"d{name}", **tol)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("gqa", [False, True])
def test_blockwise_causal_bwd_kernel_parity(dtype, gqa):
    """Pallas backward == reference VJP for dq/dk/dv/dE/dF, MHA + GQA,
    fp32 + bf16 inputs."""
    H, Hkv = (4, 2) if gqa else (2, 2)
    c, r = 16, 4
    case = _bca_grad_case(2, H, Hkv, 64, 16, c, r, dtype, (c, r))
    g_fused = _bca_grads(*case, c, r, "fused")
    g_ref = _bca_grads(*case, c, r, "reference")
    _assert_grads_close(g_fused, g_ref, dtype)


def test_blockwise_causal_bwd_per_head_projection():
    """Per-head (Hkv, c, r) E/F chain through the same compress_blocks VJP."""
    c, r = 16, 2
    case = _bca_grad_case(1, 4, 2, 48, 8, c, r, jnp.float32, (2, c, r))
    g_fused = _bca_grads(*case, c, r, "fused")
    g_ref = _bca_grads(*case, c, r, "reference")
    _assert_grads_close(g_fused, g_ref, jnp.float32)


def test_blockwise_causal_bwd_fold_boundary():
    """S exactly one block (no visible compressed slots anywhere) and
    S = 2 blocks (first fold boundary) — the global-branch edge cases."""
    for S in (16, 32):
        case = _bca_grad_case(1, 2, 1, S, 8, 16, 4, jnp.float32, (16, 4))
        g_fused = _bca_grads(*case, 16, 4, "fused")
        g_ref = _bca_grads(*case, 16, 4, "reference")
        _assert_grads_close(g_fused, g_ref, jnp.float32)


def test_blockwise_causal_bwd_residual_parity():
    """The (m, denom) residuals the fused forward saves equal the reference
    joint softmax's row max and denominator (core/causal.py export)."""
    from repro.core.causal import compress_blocks
    B, H, Hkv, S, Dh, c, r = 2, 4, 2, 64, 16, 16, 4
    q, k, v, E, F, _ = _bca_grad_case(B, H, Hkv, S, Dh, c, r, jnp.float32,
                                      (c, r))
    nb = S // c
    kbar = compress_blocks(k.reshape(B, nb, c, Hkv, Dh), E).reshape(
        B, nb * r, Hkv, Dh)
    vbar = compress_blocks(v.reshape(B, nb, c, Hkv, Dh), F).reshape(
        B, nb * r, Hkv, Dh)
    tk = lambda x: jnp.moveaxis(x, 2, 1)
    from repro.kernels import blockwise_causal_attn as bca
    out_k, m_k, d_k = bca.blockwise_causal_attn(
        tk(q), tk(k), tk(v), tk(kbar), tk(vbar), block_size=c, block_slots=r,
        scale=Dh ** -0.5, interpret=True, return_residuals=True)
    out_r, m_r, d_r = blockwise_causal_attention(
        q, k, v, E, F, block_size=c, scale=Dh ** -0.5, return_residuals=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(out_k, 1, 2)),
                               np.asarray(out_r), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               atol=2e-5, rtol=2e-5)


def test_bca_chunked_threshold_single_source():
    """The S ≥ 8192 chunked-reference threshold lives in ONE place — the
    tuned accessor in core/causal.py that every consumer imports, falling
    back to CHUNKED_ATTENTION_MIN_SEQ when the tuning table has no entry."""
    from repro.core.causal import (CHUNKED_ATTENTION_MIN_SEQ,
                                   chunked_attention_min_seq)
    from repro.models import transformer
    from repro.tune.table import TuningTable, override
    assert ops.CHUNKED_ATTENTION_MIN_SEQ is CHUNKED_ATTENTION_MIN_SEQ
    assert ops.chunked_attention_min_seq is chunked_attention_min_seq
    assert transformer.chunked_attention_min_seq is chunked_attention_min_seq
    with override(TuningTable()):
        assert chunked_attention_min_seq() == CHUNKED_ATTENTION_MIN_SEQ


@pytest.mark.slow
def test_blockwise_causal_bwd_parity_across_chunked_threshold():
    """Gradients match the reference VJP on BOTH sides of
    CHUNKED_ATTENTION_MIN_SEQ — above it the reference oracle recomputes
    through the memory-bounded chunked form, and the fused backward must
    agree with that too."""
    from repro.core.causal import CHUNKED_ATTENTION_MIN_SEQ as MIN_SEQ
    c, r = 512, 2
    for S in (MIN_SEQ - c, MIN_SEQ):
        case = _bca_grad_case(1, 2, 1, S, 8, c, r, jnp.float32, (c, r))
        g_fused = _bca_grads(*case, c, r, "fused")
        g_ref = _bca_grads(*case, c, r, "reference")
        _assert_grads_close(g_fused, g_ref, jnp.float32)


def test_bca_fused_backward_no_reference_recompute(monkeypatch):
    """Acceptance criterion: jax.grad through the DEFAULT fused backward
    never calls the jnp reference (the recompute is gone); the
    backward_impl="reference" oracle still does."""
    calls = []

    def spy(fn):
        def wrapped(*a, **kw):
            calls.append(fn.__name__)
            return fn(*a, **kw)
        return wrapped

    monkeypatch.setattr(ops, "blockwise_causal_attention",
                        spy(ops.blockwise_causal_attention))
    monkeypatch.setattr(ops, "blockwise_causal_attention_chunked",
                        spy(ops.blockwise_causal_attention_chunked))
    # unique S so the jit cache can't serve a pre-spy trace
    c, r = 16, 4
    case = _bca_grad_case(1, 2, 1, 80, 8, c, r, jnp.float32, (c, r))
    _bca_grads(*case, c, r, "fused")
    assert calls == []
    _bca_grads(*case, c, r, "reference")
    assert calls != []


def test_blockwise_causal_bwd_check_grads():
    """check_grads smoke: first-order numerical validation of the fused
    backward, and second-order of the pure-jnp oracle it is tested against.
    (Second-order THROUGH the Pallas kernels is unavailable in this
    toolchain — pallas_call's jvp rule cannot re-trace `pl.program_id`
    outside a grid context — a pre-existing limit of the fused forward,
    unchanged by the fused backward.)"""
    from jax.test_util import check_grads
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, 16, 2, 4))
    k = jax.random.normal(ks[1], (1, 16, 1, 4))
    v = jax.random.normal(ks[2], (1, 16, 1, 4))
    E = jax.random.normal(ks[3], (8, 2)) * 0.3
    F = jax.random.normal(ks[4], (8, 2)) * 0.3
    fused = lambda *a: ops.fused_blockwise_causal_attention(
        *a, block_size=8, block_slots=2, scale=0.5)
    check_grads(fused, (q, k, v, E, F), order=1, modes=["rev"],
                atol=1e-2, rtol=1e-2)
    oracle = lambda *a: blockwise_causal_attention(*a, block_size=8,
                                                   scale=0.5)
    check_grads(oracle, (q, k, v, E, F), order=2, modes=["rev"],
                atol=1e-2, rtol=1e-2)
