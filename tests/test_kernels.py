"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.causal import blockwise_causal_attention
from repro.kernels import ops, ref

SHAPES = [  # (B, H, Hkv, S, Dh, K)
    (1, 2, 2, 64, 16, 8),
    (2, 4, 2, 128, 32, 16),
    (1, 8, 4, 256, 64, 32),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_linformer_attn_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    kbar = jax.random.normal(ks[1], (B, K, Hkv, Dh), dtype)
    vbar = jax.random.normal(ks[2], (B, K, Hkv, Dh), dtype)
    scale = Dh ** -0.5
    out = ops.fused_linformer_attention(q, kbar, vbar, scale=scale,
                                        block_q=min(64, S))
    qk = jnp.moveaxis(q, 2, 1)
    kb = jnp.repeat(jnp.moveaxis(kbar, 2, 1), H // Hkv, 1)
    vb = jnp.repeat(jnp.moveaxis(vbar, 2, 1), H // Hkv, 1)
    expect = jnp.moveaxis(ref.linformer_attn_ref(qk, kb, vb, scale), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_seq_projection_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, Dh), dtype)
    E = (jax.random.normal(jax.random.PRNGKey(2), (S, K)) * 0.2).astype(dtype)
    out = ops.fused_seq_projection(x, E, block_s=min(64, S))
    expect = jnp.moveaxis(
        ref.seq_projection_ref(jnp.moveaxis(x, 2, 1), E), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_blockwise_causal_kernel(shape, dtype):
    B, H, Hkv, S, Dh, K = shape
    c, r = 32, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
    E = jax.random.normal(ks[3], (c, r)) * 0.3
    F = jax.random.normal(ks[4], (c, r)) * 0.3
    scale = Dh ** -0.5
    out = ops.fused_blockwise_causal_attention(
        q, k, v, E, F, block_size=c, block_slots=r, scale=scale)
    expect = blockwise_causal_attention(q, k, v, E, F, block_size=c,
                                        scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=5e-5, rtol=5e-5)


def test_seq_projection_accumulator_matches_single_block():
    """Multi-block accumulation must equal one big block (fp32 accumulate)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 32), jnp.float32)
    E = jax.random.normal(jax.random.PRNGKey(2), (256, 16)) * 0.2
    a = ops.fused_seq_projection(x, E, block_s=32)
    b = ops.fused_seq_projection(x, E, block_s=256)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_linformer_attn_custom_vjp_matches_autodiff():
    """The fused kernel is trainable: its analytic VJP equals autodiff of
    the pure-jnp reference (including the GQA head-repeat fold)."""
    from repro.core.linformer import attend_compressed
    B, H, Hkv, S, Dh, K = 1, 4, 2, 64, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    kb = jax.random.normal(ks[1], (B, K, Hkv, Dh))
    vb = jax.random.normal(ks[2], (B, K, Hkv, Dh))
    scale = Dh ** -0.5

    def via_kernel(q, kb, vb):
        return jnp.sum(ops.fused_linformer_attention(
            q, kb, vb, scale=scale, block_q=32) ** 2)

    def via_ref(q, kb, vb):
        return jnp.sum(attend_compressed(q, kb, vb, scale=scale) ** 2)

    g1 = jax.grad(via_kernel, argnums=(0, 1, 2))(q, kb, vb)
    g2 = jax.grad(via_ref, argnums=(0, 1, 2))(q, kb, vb)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


def test_linformer_attn_rows_sum_to_one_property():
    """Kernel softmax: uniform values -> output equals that value."""
    B, H, S, Dh, K = 1, 2, 64, 16, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh))
    kbar = jax.random.normal(jax.random.PRNGKey(1), (B, K, H, Dh))
    vbar = jnp.full((B, K, H, Dh), 0.731)
    out = ops.fused_linformer_attention(q, kbar, vbar, scale=0.25,
                                        block_q=32)
    np.testing.assert_allclose(out, jnp.full_like(out, 0.731), atol=1e-5)
