"""Document packing + file-backed corpus."""
import numpy as np
import pytest

from repro.data.packing import FileCorpus, pack_documents, packing_efficiency
from repro.data.pipeline import BOS, EOS, PAD, ByteTokenizer


class TestPacking:
    def test_roundtrip_contents(self):
        docs = [np.arange(10, 20), np.arange(30, 35), np.arange(50, 90)]
        out = pack_documents(docs, seq_len=16)
        flat = np.concatenate([out["tokens"].ravel(),
                               out["labels"][:, -1:].ravel()])
        # every document token appears
        for d in docs:
            for t in d:
                assert t in flat

    def test_labels_are_shifted_tokens(self):
        docs = [np.arange(10, 40)]
        out = pack_documents(docs, seq_len=8)
        np.testing.assert_array_equal(out["tokens"][:, 1:],
                                      out["labels"][:, :-1])

    def test_cross_document_positions_masked(self):
        docs = [np.arange(10, 14), np.arange(20, 24)]   # both fit in one row
        out = pack_documents(docs, seq_len=16)
        toks, labels, mask = out["tokens"][0], out["labels"][0], \
            out["loss_mask"][0]
        # the position whose label is the second doc's BOS must be masked
        boundary = [i for i in range(len(labels))
                    if labels[i] == BOS and toks[i] == EOS]
        assert boundary
        for i in boundary:
            assert mask[i] == 0
        # pad labels masked
        assert (mask[labels == PAD] == 0).all()

    def test_long_document_spans_rows(self):
        docs = [np.arange(10, 110)]                      # 100 tokens, seq 16
        out = pack_documents(docs, seq_len=16)
        assert out["tokens"].shape[0] >= 6
        assert packing_efficiency(out) > 0.9

    def test_packing_efficiency_beats_padding(self):
        rng = np.random.default_rng(0)
        docs = [np.arange(s) + 10 for s in rng.integers(5, 60, 50)]
        out = pack_documents(docs, seq_len=64)
        eff = packing_efficiency(out)
        # padding each doc to 64 would give mean(len)/64 ≈ 0.5 efficiency
        assert eff > 0.85

    def test_empty(self):
        out = pack_documents([], seq_len=8)
        assert out["tokens"].shape == (0, 8)


class TestFileCorpus:
    def test_reads_and_packs(self, tmp_path):
        (tmp_path / "a.txt").write_text("hello world, this is doc a. " * 20)
        (tmp_path / "b.txt").write_text("doc b is shorter.")
        fc = FileCorpus(str(tmp_path), seq_len=64, seed=0)
        batches = list(fc.batches(batch_size=2, epoch=0))
        assert batches
        b = batches[0]
        assert b["tokens"].shape == (2, 64)
        assert b["loss_mask"].max() == 1
        # decodes back to text fragments
        text = ByteTokenizer().decode(b["tokens"][0])
        assert "doc" in text or "hello" in text

    def test_epoch_shuffling_deterministic(self, tmp_path):
        for i in range(4):
            (tmp_path / f"{i}.txt").write_text(f"document number {i} " * 30)
        fc1 = FileCorpus(str(tmp_path), seq_len=32, seed=7)
        fc2 = FileCorpus(str(tmp_path), seq_len=32, seed=7)
        b1 = next(fc1.batches(1, epoch=3))
        b2 = next(fc2.batches(1, epoch=3))
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = next(fc1.batches(1, epoch=4))
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_missing_dir(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            FileCorpus(str(tmp_path), seq_len=32)
