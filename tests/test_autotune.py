"""Autotuner + tuning table: file round-trip through the attention
plan's resolution, bucket boundaries, corrupt/missing-table fallback,
winner determinism under an injected timer, serving byte-parity
tuned-vs-default, lookup-stats telemetry drain, and the check_tuning
CLI legs."""
import json
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.kernels import common as kcommon
from repro.kernels import ops as kernel_ops
from repro.models import model as M
from repro.parallel.plan import AttentionPlan
from repro.serving import ServingEngine
from repro.tune import autotune as autotune_lib
from repro.tune import table as tuning
from repro.tune.table import (TuningTable, clear_table_cache, consume_stats,
                              next_pow2, override, shape_bucket)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PLATFORM = jax.default_backend()


@pytest.fixture(autouse=True)
def _fresh_table_state():
    """Every test starts from an unresolved module table and clean
    counters, and cannot leak its table (or stats) into the next."""
    clear_table_cache()
    consume_stats()
    yield
    clear_table_cache()
    consume_stats()


def _exact_table(bq, bs, *, seq, slots, heads):
    t = TuningTable()
    t.add(platform=PLATFORM, form="exact",
          bucket=shape_bucket(seq=seq, slots=slots, heads=heads,
                              dtype="float32"),
          params={"block_q": bq, "block_s": bs},
          trial_us=1.0, default_us=2.0, trials=1)
    return t


# ---------------------------------------------------------------------------
# file round-trip -> the attention plan launches with the tuned blocks
# ---------------------------------------------------------------------------


class TestPlanResolution:
    def test_saved_table_reaches_the_fused_call_site(self, tmp_path,
                                                     monkeypatch):
        """save -> REPRO_TUNING_PATH -> plan.exact_attention: the kernels
        must be launched with the tuned block_q/block_s, through the real
        file + env-var path (not an in-process override)."""
        path = tmp_path / "TUNING.json"
        _exact_table(32, 16, seq=64, slots=16, heads=4).save(str(path))
        monkeypatch.setenv(tuning.ENV_PATH, str(path))
        clear_table_cache()
        seen = {}
        real_attn = kernel_ops.fused_linformer_attention
        real_proj = kernel_ops.fused_seq_projection

        def spy_attn(q, kbar, vbar, **kw):
            seen["block_q"] = kw.get("block_q")
            return real_attn(q, kbar, vbar, **kw)

        def spy_proj(x, E, **kw):
            seen["block_s"] = kw.get("block_s")
            return real_proj(x, E, **kw)

        monkeypatch.setattr(kernel_ops, "fused_linformer_attention",
                            spy_attn)
        monkeypatch.setattr(kernel_ops, "fused_seq_projection", spy_proj)
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (1, 64, 4, 8), jnp.float32)
        k = jax.random.normal(key, (1, 64, 4, 8), jnp.float32)
        v = jax.random.normal(key, (1, 64, 4, 8), jnp.float32)
        E = jax.random.normal(key, (64, 16), jnp.float32) / 8.0
        plan = AttentionPlan(backend="fused")
        out = plan.exact_attention(q, k, v, E, E, projection="linear",
                                   scale=8 ** -0.5)
        assert out.shape == (1, 64, 4, 8)
        assert seen == {"block_q": 32, "block_s": 16}

    def test_default_blocks_without_a_table(self):
        with override(TuningTable()):
            kw = dict(seq=64, slots=16, heads=4, dtype="float32")
            assert tuning.block_q_for(**kw) == kcommon.DEFAULT_BLOCK_Q
            assert tuning.block_s_for(**kw) == kcommon.DEFAULT_BLOCK_S
            assert tuning.q_chunk_blocks_for(seq=64) == \
                kcommon.DEFAULT_Q_CHUNK_BLOCKS

    def test_block_q_is_bitwise_invariant(self):
        """The contract RL006 + the tuner rely on: block_q partitions
        independent query rows, so ANY tuned value is byte-identical."""
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (2, 64, 4, 8), jnp.float32)
        kbar = jax.random.normal(key, (2, 16, 4, 8), jnp.float32)
        vbar = jax.random.normal(key, (2, 16, 4, 8), jnp.float32)
        outs = [np.asarray(kernel_ops.fused_linformer_attention(
                    q, kbar, vbar, scale=0.5, block_q=bq))
                for bq in (8, 32, 64)]
        assert all(np.array_equal(outs[0], o) for o in outs[1:])


# ---------------------------------------------------------------------------
# bucket boundaries
# ---------------------------------------------------------------------------


class TestBuckets:
    def test_next_pow2_boundaries(self):
        assert [next_pow2(n) for n in (511, 512, 513)] == [512, 512, 1024]

    def test_lookup_across_the_pow2_boundary(self):
        t = TuningTable()
        t.add(platform=PLATFORM, form="exact", bucket={"seq": 512},
              params={"block_q": 32}, trial_us=1.0, default_us=2.0,
              trials=1)
        with override(t):
            kw = dict(slots=16, heads=4, dtype="float32")
            assert tuning.block_q_for(seq=511, **kw) == 32
            assert tuning.block_q_for(seq=512, **kw) == 32
            # 513 buckets to 1024 — no entry, hand-picked default
            assert tuning.block_q_for(seq=513, **kw) == \
                kcommon.DEFAULT_BLOCK_Q

    def test_most_specific_bucket_wins(self):
        t = TuningTable()
        t.add(platform=PLATFORM, form="exact", bucket={"seq": 512},
              params={"block_q": 32}, trial_us=1.0, default_us=1.0,
              trials=1)
        t.add(platform=PLATFORM, form="exact",
              bucket={"seq": 512, "heads": 8},
              params={"block_q": 64}, trial_us=1.0, default_us=1.0,
              trials=1)
        with override(t):
            kw = dict(seq=512, slots=16, dtype="float32")
            assert tuning.block_q_for(heads=8, **kw) == 64
            assert tuning.block_q_for(heads=4, **kw) == 32


# ---------------------------------------------------------------------------
# corrupt / missing table -> silent fallback to defaults
# ---------------------------------------------------------------------------


class TestFallback:
    def _assert_defaults(self):
        kw = dict(seq=64, slots=16, heads=4, dtype="float32")
        assert tuning.block_q_for(**kw) == kcommon.DEFAULT_BLOCK_Q
        assert tuning.scalar("decode_chunk", 32) == 32

    def test_missing_file(self, monkeypatch):
        monkeypatch.setenv(tuning.ENV_PATH, "/nonexistent/TUNING.json")
        clear_table_cache()
        self._assert_defaults()

    def test_unparseable_json(self, tmp_path, monkeypatch):
        p = tmp_path / "TUNING.json"
        p.write_text("{this is not json")
        monkeypatch.setenv(tuning.ENV_PATH, str(p))
        clear_table_cache()
        self._assert_defaults()

    def test_schema_invalid_doc(self, tmp_path, monkeypatch):
        p = tmp_path / "TUNING.json"
        p.write_text(json.dumps({
            "version": 1,
            "entries": [{"platform": PLATFORM, "form": "exact",
                         "bucket": {"seq": 64},
                         "params": {"block_q": 0},   # < 1: invalid
                         "trial_us": 1.0, "default_us": 1.0,
                         "speedup": 1.0, "trials": 1}]}))
        monkeypatch.setenv(tuning.ENV_PATH, str(p))
        clear_table_cache()
        self._assert_defaults()

    def test_misses_are_counted(self):
        with override(TuningTable()):
            consume_stats()
            tuning.block_q_for(seq=64, slots=16, heads=4, dtype="float32")
            assert consume_stats()["misses"] >= 1


# ---------------------------------------------------------------------------
# winner determinism with an injected timer (no real timing, no noise)
# ---------------------------------------------------------------------------


def _fake_timer(label):
    """bq64_bs128 is the global winner; bs128 wins the first pass."""
    if label.endswith("bq64_bs128"):
        return 5.0
    if label.endswith("_bs128"):
        return 7.0
    return 9.0


class TestWinnerDeterminism:
    def test_exact_sweep_is_deterministic(self):
        tables = []
        for _ in range(2):
            t = TuningTable()
            autotune_lib.tune_exact(t, shapes=[(256, 64, 2, 2, 8)],
                                    iters=1, timer=_fake_timer)
            tables.append(t)
        assert tables[0].entries == tables[1].entries
        (e,) = tables[0].entries
        assert e["params"] == {"block_q": 64, "block_s": 128}
        assert e["trial_us"] == 5.0
        # default combo (bq 256, bs 256 after divisor clamp at S=256)
        # was timed in the first pass at 9.0
        assert e["default_us"] == 9.0
        assert e["speedup"] == 1.8

    def test_causal_sweep_picks_injected_winner(self):
        timer = lambda label: 3.0 if label.endswith("qcb4") else 8.0
        t = TuningTable()
        autotune_lib.tune_causal_chunked(t, shapes=[(512, 64, 8, 2, 2, 16)],
                                         iters=1, timer=timer)
        (e,) = t.entries
        assert e["params"] == {"q_chunk_blocks": 4}
        assert e["bucket"] == {"seq": 512}

    def test_trials_are_counted(self):
        from repro.telemetry import Telemetry
        tel = Telemetry()
        t = TuningTable()
        autotune_lib.tune_exact(t, shapes=[(256, 64, 2, 2, 8)], iters=1,
                                telemetry=tel, timer=_fake_timer)
        n = tel.metrics.counter("autotune_trials_total").value
        # S=256: {128,256} x first pass + {64,128,256} second pass
        assert n == 5


# ---------------------------------------------------------------------------
# serving byte-parity: tuned scalars must never change token streams
# ---------------------------------------------------------------------------


class TestServingParity:
    def _cfg(self, max_seq=64):
        return ModelConfig(
            name="autotune-parity", num_layers=2, d_model=32,
            vocab_size=256, max_seq_len=max_seq,
            attention=AttentionConfig(
                kind="linformer_causal", num_heads=4, num_kv_heads=2,
                head_dim=8,
                linformer=LinformerConfig(block_size=8, block_slots=4)),
            dtype="float32", remat="none")

    def test_tuned_decode_chunk_is_byte_identical(self):
        """decode_chunk resolved from the table changes tick granularity
        only (the decode-chunk-invariance contract): same prompts, same
        greedy token streams, byte for byte."""
        cfg = self._cfg()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(5)
        prompts = [list(rng.integers(4, 256, 8)) for _ in range(3)]
        budgets = [6, 4, 6]

        def serve_with(table):
            with override(table):
                eng = ServingEngine(params, cfg, max_seq=64,
                                    cache_dtype=jnp.float32)
                assert eng.decode_chunk == (
                    table.scalar("decode_chunk", 32)
                    if table.entries else 32)
                return eng.serve(prompts, budgets, max_batch=2)

        tuned = TuningTable()
        tuned.add(platform=PLATFORM, form="scalars", bucket=None,
                  params={"decode_chunk": 2}, trial_us=1.0,
                  default_us=1.0, trials=1)
        assert serve_with(TuningTable()) == serve_with(tuned)


# ---------------------------------------------------------------------------
# lookup-stats drain (the engine's tuning_table_* counters)
# ---------------------------------------------------------------------------


class TestStatsDrain:
    def test_note_table_stats_exports_counters(self):
        from repro.telemetry import Telemetry
        t = TuningTable()
        t.add(platform=PLATFORM, form="scalars", bucket=None,
              params={"decode_chunk": 8}, trial_us=1.0, default_us=1.0,
              trials=1)
        with override(t):
            consume_stats()
            assert tuning.scalar("decode_chunk", 32) == 8       # hit
            tuning.block_q_for(seq=8, slots=8, heads=1,
                               dtype="float32")                 # miss
            tel = Telemetry()
            host = types.SimpleNamespace(telemetry=tel)
            ServingEngine._note_table_stats(host, tel)
            assert tel.metrics.counter(
                "tuning_table_hit_total").value == 1
            assert tel.metrics.counter(
                "tuning_table_miss_total").value == 1
            # drained: a second call adds nothing
            ServingEngine._note_table_stats(host, tel)
            assert tel.metrics.counter(
                "tuning_table_hit_total").value == 1


# ---------------------------------------------------------------------------
# check_tuning CLI (scripts/_checklib convention)
# ---------------------------------------------------------------------------


class TestCheckTuningCli:
    def _run(self, *args):
        return subprocess.run(
            [sys.executable, os.path.join(ROOT, "scripts",
                                          "check_tuning.py"), *args],
            capture_output=True, text=True, cwd=ROOT)

    def test_valid_table_exits_zero(self, tmp_path):
        p = tmp_path / "t.json"
        _exact_table(32, 16, seq=64, slots=16, heads=4).save(str(p))
        r = self._run(str(p))
        assert r.returncode == 0, r.stderr

    def test_corrupt_table_exits_one_with_findings(self, tmp_path):
        p = tmp_path / "t.json"
        p.write_text('{"version": 99}')
        r = self._run("--json", "-", str(p))
        assert r.returncode == 1
        doc = json.loads(r.stdout)
        assert doc["check"] == "check_tuning" and not doc["ok"]
        assert any("version" in f["msg"] for f in doc["findings"])

    def test_missing_ok_skips_absent_tables(self, tmp_path):
        p = tmp_path / "t.json"
        _exact_table(32, 16, seq=64, slots=16, heads=4).save(str(p))
        r = self._run("--missing-ok", str(tmp_path / "absent.json"),
                      str(p))
        assert r.returncode == 0, r.stderr
        r2 = self._run(str(tmp_path / "absent.json"))
        assert r2.returncode == 1
