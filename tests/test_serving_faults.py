"""Fault-injection harness: every injected fault is detected, the faulty
request completes byte-identically after requeue (from snapshot or from
scratch), and co-resident requests' outputs never change. Negative legs
prove the injected corruption is real (silent mode diverges), so the
recovery results are not vacuous.

`REPRO_FAULT_SEED` selects the randomized schedule's seed (scripts/check.sh
runs this file with a pinned seed as the fault-injection CI leg)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.models import model as M
from repro.serving import (Fault, FaultInjector, Request, ServingEngine,
                           ShedResult)
from repro.serving.faults import (FAULT_KINDS, NAN_LOGITS, SLOT_STEP,
                                  SNAPSHOT_CORRUPT)
from repro.serving.snapshot import capture

FAULT_SEED = int(os.environ.get("REPRO_FAULT_SEED", "0"))


def _tiny_cfg(max_seq=64):
    return ModelConfig(
        name="faults-test",
        num_layers=2,
        d_model=32,
        vocab_size=256,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,
            head_dim=8,
            linformer=LinformerConfig(block_size=8, block_slots=4),
        ),
        dtype="float32",
        remat="none",
    )


def _engine(prefill_chunk=0, decode_chunk=4):
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, max_seq=64, cache_dtype=jnp.float32,
                         decode_chunk=decode_chunk,
                         prefill_chunk=prefill_chunk)


def _requests(n=8, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(4, 256, int(rng.choice([8, 9, 16, 19]))))
               for _ in range(n)]
    budgets = [int(rng.choice([3, 6, 10])) for _ in range(n)]
    return prompts, budgets


# ---------------------------------------------------------------------------
# Request validation (fail fast at construction, rid in the message)
# ---------------------------------------------------------------------------


class TestRequestValidation:
    def test_bad_fields_raise_with_rid(self):
        with pytest.raises(ValueError, match="request 7"):
            Request(rid=7, tokens=(), max_new_tokens=4)
        with pytest.raises(ValueError, match="request 8.*max_new_tokens"):
            Request(rid=8, tokens=(1, 2), max_new_tokens=0)
        with pytest.raises(ValueError, match="request 9.*max_new_tokens"):
            Request(rid=9, tokens=(1, 2), max_new_tokens=-3)
        with pytest.raises(ValueError, match="request 10.*arrival_chunk"):
            Request(rid=10, tokens=(1, 2), max_new_tokens=4,
                    arrival_chunk=-1)
        with pytest.raises(ValueError, match="request 11.*deadline_ticks"):
            Request(rid=11, tokens=(1, 2), max_new_tokens=4,
                    deadline_ticks=-5)

    def test_valid_defaults_accepted(self):
        r = Request(rid=0, tokens=(1, 2, 3), max_new_tokens=4)
        assert r.priority == 0 and r.deadline_ticks is None

    def test_serve_rejects_nonpositive_budget(self):
        eng = _engine()
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.serve([[1, 2, 3]], max_new_tokens=0, max_batch=2)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.serve_static([[1, 2, 3]], max_new_tokens=0, max_batch=2)

    def test_bad_fault_kind_raises(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault(kind="cosmic_ray", chunk=0)


# ---------------------------------------------------------------------------
# Detection + recovery: the harness contract
# ---------------------------------------------------------------------------


class TestFaultRecovery:
    @pytest.mark.parametrize("prefill_chunk", [0, 8])
    @pytest.mark.parametrize("kind", FAULT_KINDS)
    def test_fault_detected_and_recovered_byte_identical(self, kind,
                                                         prefill_chunk):
        """One injected fault of each kind, both admission modes: the fault
        is detected (quarantine), the faulty request completes
        byte-identically after requeue, and every co-resident request's
        output equals the fault-free run."""
        eng = _engine(prefill_chunk=prefill_chunk)
        prompts, budgets = _requests(8)
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        inj = FaultInjector([Fault(kind, chunk=2, row=1)])
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               snapshot_chunks=2, fault_injector=inj,
                               return_scheduler=True)
        assert len(inj.fired) == 1
        assert sched.stats.quarantines == 1      # detected, isolated
        assert sched.stats.retries == 1          # requeued, not dropped
        if kind == SNAPSHOT_CORRUPT:
            # the flipped byte must be caught by the checksum at restore
            assert sched.stats.snapshot_corruptions == 1
        assert out == clean                      # faulty row AND neighbours

    def test_nan_guard_quarantines_instead_of_streaming(self):
        """Poisoned logits are caught at the chunk's host sync: no garbage
        token reaches on_token, and the streamed sequence equals the final
        output for every request."""
        eng = _engine()
        prompts, budgets = _requests(8)
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        streamed = {i: [] for i in range(len(prompts))}
        inj = FaultInjector([Fault(NAN_LOGITS, chunk=1, row=0)])
        out, sched = eng.serve(
            prompts, budgets, max_batch=4, snapshot_chunks=1,
            fault_injector=inj, return_scheduler=True,
            on_token=lambda rid, tok: streamed[rid].append(tok))
        assert sched.stats.quarantines == 1
        assert out == clean
        for i, o in enumerate(out):
            assert streamed[i] == o, f"rid {i} streamed garbage"

    def test_nan_guard_off_streams_garbage(self):
        """Negative control: with the guard disabled the same NaN poison
        visibly corrupts the output — proving the injection is real and the
        guard (not luck) is what protects the positive test."""
        eng = _engine()
        prompts, budgets = _requests(8)
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        inj = FaultInjector([Fault(NAN_LOGITS, chunk=1, row=0)])
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               nan_guard=False, fault_injector=inj,
                               return_scheduler=True)
        assert sched.stats.quarantines == 0
        assert out != clean

    def test_undetectable_garble_diverges(self):
        """Negative control for slot_step: detectable=False keeps the cache
        corruption but silences the failure report, so the run streams
        wrong tokens — recovery in the positive test is not vacuous."""
        eng = _engine()
        prompts, budgets = _requests(8)
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        inj = FaultInjector([Fault(SLOT_STEP, chunk=1, row=0)],
                            detectable=False)
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               fault_injector=inj, return_scheduler=True)
        assert sched.stats.quarantines == 0
        assert out != clean

    def test_randomized_schedule_all_detected(self):
        """Seeded random schedule (the CI leg's seed via REPRO_FAULT_SEED):
        every fired fault is detected and quarantined, and with a retry
        budget covering the fault count every request still completes
        byte-identically."""
        eng = _engine(prefill_chunk=8)
        prompts, budgets = _requests(8)
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        inj = FaultInjector(seed=FAULT_SEED, n_random=3, horizon=10)
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               snapshot_chunks=2, max_retries=5,
                               fault_injector=inj, return_scheduler=True)
        assert len(inj.fired) + len(inj.skipped) >= 3
        assert sched.stats.quarantines == len(inj.fired)
        assert out == clean

    def test_retries_exhausted_sheds_explicitly(self):
        """A request hammered past max_retries is shed with an explicit
        ShedResult (reason recorded), never silently dropped or left
        spinning."""
        eng = _engine()
        prompts, budgets = _requests(4)
        inj = FaultInjector([Fault(SLOT_STEP, chunk=c, row=0)
                             for c in range(12)])
        out, sched = eng.serve(prompts, budgets, max_batch=1, max_retries=1,
                               fault_injector=inj, return_scheduler=True)
        shed = [o for o in out if isinstance(o, ShedResult)]
        assert shed and all(o.reason == "retries_exhausted" for o in shed)
        assert sched.stats.sheds == len(shed)
        # the rest still completed correctly
        clean = eng.serve_static(prompts, budgets, max_batch=4)
        for o, c in zip(out, clean):
            assert isinstance(o, ShedResult) or o == c


# ---------------------------------------------------------------------------
# Snapshot integrity primitives
# ---------------------------------------------------------------------------


def _paged_engine(prefill_chunk=0, **kw):
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, max_seq=64, cache_dtype=jnp.float32,
                         decode_chunk=4, prefill_chunk=prefill_chunk,
                         cache_format="paged", **kw)


class TestPagedSnapshotScales:
    """A quantized cache is only as good as its scales: the snapshot CRC
    must cover the fp32 scale leaves, and the paged fault path must detect
    and recover scale corruption exactly like payload corruption."""

    def _paged_snapshot(self):
        from repro.serving.scheduler import SlotPool
        eng = _paged_engine()
        pool = SlotPool(eng, max_batch=2)
        prompt = list(range(4, 23))            # 19 tokens -> 2 committed pages
        cache, logits = eng.prefill(np.asarray([prompt], np.int32))
        req = Request(rid=0, tokens=tuple(prompt), max_new_tokens=4)
        pool.admit(0, req, cache, int(jnp.argmax(logits[0])))
        return pool.snapshot_rows([0], tick=0)[0]

    def test_paged_snapshot_carries_scale_leaves(self):
        snap = self._paged_snapshot()
        for key in ("pages_k_s", "pages_v_s", "raw_k_s", "raw_v_s"):
            leaf = snap.cache_rows[key]
            assert leaf.dtype == np.float32 and leaf.size > 0, key
        # the quantized payloads ride as integers, not floats
        assert snap.cache_rows["pages_k"].dtype != np.float32
        assert snap.verify()

    @pytest.mark.parametrize("key", ["pages_k_s", "pages_v_s",
                                     "raw_k_s", "raw_v_s"])
    def test_scale_only_flip_fails_verify(self, key):
        """Flipping a single byte of ONE scale leaf — payloads untouched —
        must fail verify() exactly like a payload flip."""
        snap = self._paged_snapshot()
        assert snap.verify()
        flat = snap.cache_rows[key].reshape(-1).view(np.uint8)
        flat[0] ^= 0xFF
        assert not snap.verify()

    def test_payload_flip_still_detected(self):
        snap = self._paged_snapshot()
        flat = snap.cache_rows["pages_k"].reshape(-1).view(np.uint8)
        flat[1] ^= 0xFF
        assert not snap.verify()

    def test_injector_targets_scale_leaves(self):
        """The snapshot_corrupt fault draws its victim leaf uniformly over
        ALL keys, so fp32 scale leaves are real targets (the regression this
        class guards: an injector pinned to the first sorted key would never
        exercise the scales)."""
        snap = self._paged_snapshot()
        keys = sorted(snap.cache_rows)
        assert any(k.endswith("_s") for k in keys)
        rng = np.random.default_rng(0)
        hit = {keys[int(rng.integers(len(keys)))] for _ in range(256)}
        assert any(k.endswith("_s") for k in hit)

    @pytest.mark.parametrize("kind", FAULT_KINDS)
    def test_paged_fault_detected_and_recovered(self, kind):
        """Each fault kind on a paged pool: detected, quarantined, and the
        run still matches the fault-free paged run byte-identically. NaN
        poison reaches the model through the fp32 SCALE leaves (int8
        payloads cannot hold a NaN), so this leg proves the scales are a
        live fault surface, not dead bytes."""
        eng = _paged_engine()
        prompts, budgets = _requests(8)
        clean = eng.serve(prompts, budgets, max_batch=4)
        inj = FaultInjector([Fault(kind, chunk=2, row=1)])
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               snapshot_chunks=2, fault_injector=inj,
                               return_scheduler=True)
        assert len(inj.fired) == 1
        assert sched.stats.quarantines == 1
        if kind == SNAPSHOT_CORRUPT:
            assert sched.stats.snapshot_corruptions == 1
        assert out == clean
        sched.pool.alloc.check()     # no page leaked through quarantine


class TestSnapshotChecksum:
    def _snap(self):
        rows = {"comp_k": np.arange(24, dtype=np.float32).reshape(2, 1, 3, 4),
                "lengths": np.asarray([5], np.int32)}
        return capture(rid=1, state="decoding", filled=5, cur=7,
                       finished=False, emitted=[1, 2], cache_rows=rows,
                       tick=3)

    def test_verify_roundtrip(self):
        snap = self._snap()
        assert snap.verify()
        assert snap.nbytes > 0

    def test_bitflip_detected(self):
        snap = self._snap()
        flat = snap.cache_rows["comp_k"].reshape(-1).view(np.uint8)
        flat[3] ^= 0xFF
        assert not snap.verify()

    def test_capture_copies(self):
        """Mutating the source after capture must not alter the snapshot."""
        rows = {"x": np.ones((2, 1), np.float32)}
        snap = capture(rid=0, state="decoding", filled=0, cur=1,
                       finished=False, emitted=[], cache_rows=rows, tick=0)
        rows["x"][:] = 9.0
        assert snap.verify()
