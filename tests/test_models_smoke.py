"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs forward + one train step on CPU with correct shapes and
no NaNs; decode-capable families also check decode == forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_IDS, ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import OptimizerConfig
from repro.models import model as M
from repro.optim import adamw_init, adamw_update
from tests.conftest import f32, make_batch


@pytest.mark.parametrize("arch", ALL_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = f32(get_smoke_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux, _ = M.forward(params, cfg, batch)
    S = 32 if cfg.embedding_inputs else 32 - cfg.frontend_embed_len \
        + cfg.frontend_embed_len
    assert logits.shape == (2, S, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_IDS)
def test_one_train_step_no_nans(arch):
    cfg = f32(get_smoke_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
    opt = adamw_init(params, ocfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    new_params, opt = adamw_update(grads, opt, params, ocfg,
                                   jnp.asarray(1e-3))
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all())
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-8b", "nemotron-4-15b",
                                  "kimi-k2-1t-a32b", "zamba2-1.2b",
                                  "rwkv6-1.6b", "musicgen-large"])
def test_decode_matches_forward(arch):
    cfg = f32(get_smoke_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B=B, S=S)
    if cfg.frontend_embed_len:
        pytest.skip("vlm decode covered via transformer family")
    logits_full, _, _ = M.forward(params, cfg, batch)
    cache = M.init_cache(cfg, batch=B, max_seq=64, dtype=jnp.float32)
    outs = []
    for t in range(S):
        if cfg.embedding_inputs:
            bt = {"embeds": batch["embeds"][:, t:t + 1]}
        else:
            bt = {"tokens": batch["tokens"][:, t:t + 1]}
        lg, cache = M.decode_step(params, cfg, bt, cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full config must carry the exact published numbers."""
    spec = {
        "qwen3-8b": dict(num_layers=36, d_model=4096, H=32, kv=8,
                         d_ff=12288, vocab=151936),
        "qwen3-14b": dict(num_layers=40, d_model=5120, H=40, kv=8,
                          d_ff=17408, vocab=151936),
        "nemotron-4-15b": dict(num_layers=32, d_model=6144, H=48, kv=8,
                               d_ff=24576, vocab=256000),
        "qwen1.5-110b": dict(num_layers=80, d_model=8192, H=64, kv=8,
                             d_ff=49152, vocab=152064),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, H=64, kv=8,
                                d_ff=2048, vocab=163840, experts=384, topk=8),
        "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, H=32, kv=4,
                                  d_ff=768, vocab=151936, experts=128,
                                  topk=8),
        "internvl2-2b": dict(num_layers=24, d_model=2048, H=16, kv=8,
                             d_ff=8192, vocab=92553),
        "zamba2-1.2b": dict(num_layers=38, d_model=2048, H=32, kv=32,
                            d_ff=8192, vocab=32000, ssm_state=64),
        "musicgen-large": dict(num_layers=48, d_model=2048, H=32, kv=32,
                               d_ff=8192, vocab=2048),
        "rwkv6-1.6b": dict(num_layers=24, d_model=2048, d_ff=7168,
                           vocab=65536),
    }[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == spec["num_layers"]
    assert cfg.d_model == spec["d_model"]
    assert cfg.vocab_size == spec["vocab"]
    if "H" in spec:
        assert cfg.attention.num_heads == spec["H"]
        assert cfg.attention.num_kv_heads == spec["kv"]
    if "experts" in spec:
        assert cfg.moe.num_experts == spec["experts"]
        assert cfg.moe.top_k == spec["topk"]
        assert cfg.moe.expert_d_ff == spec["d_ff"]
    else:
        assert cfg.mlp.d_ff == spec["d_ff"]
    if "ssm_state" in spec:
        assert cfg.ssm.state_dim == spec["ssm_state"]


def test_arch_feature_flags():
    assert get_config("qwen3-8b").attention.qk_norm
    assert get_config("qwen1.5-110b").attention.qkv_bias
    assert get_config("nemotron-4-15b").mlp.activation == "squared_relu"
    assert get_config("musicgen-large").embedding_inputs
    assert get_config("internvl2-2b").frontend_embed_len > 0
    assert get_config("rwkv6-1.6b").family == "ssm"
    assert get_config("zamba2-1.2b").family == "hybrid"


def test_param_count_estimates():
    """Sanity: estimates land near published sizes."""
    est = get_config("qwen3-8b").param_count_estimate
    assert 6e9 < est < 10e9
    est = get_config("qwen1.5-110b").param_count_estimate
    assert 90e9 < est < 130e9
    kimi = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < kimi.param_count_estimate < 1.3e12
    assert 20e9 < kimi.active_param_count_estimate < 45e9
