"""Continuous-batching scheduler: continuous-vs-static parity, per-row
position-counter decode parity, pool-owner donation safety, streaming."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.core.cache import (compressed_decode_attention,
                              full_decode_attention, init_compressed_cache)
from repro.models import model as M
from repro.serving import (Request, Scheduler, ServingEngine, ShedResult,
                           SlotPool)


def _tiny_cfg(max_seq=64):
    return ModelConfig(
        name="sched-test",
        num_layers=2,
        d_model=32,
        vocab_size=256,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,          # GQA
            head_dim=8,
            linformer=LinformerConfig(block_size=8, block_slots=4),
        ),
        dtype="float32",
        remat="none",
    )


def _engine(max_seq=64, decode_chunk=4, temperature=0.0, backend=None,
            prefill_chunk=0):
    cfg = _tiny_cfg(max_seq)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_seq=max_seq,
                        cache_dtype=jnp.float32, temperature=temperature,
                        decode_chunk=decode_chunk,
                        attention_backend=backend,
                        prefill_chunk=prefill_chunk)
    return eng, cfg, params


def _requests(n=8, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(4, 256, int(rng.choice([8, 9, 16, 19]))))
               for _ in range(n)]
    budgets = [int(rng.choice([3, 6, 10])) for _ in range(n)]
    return prompts, budgets


# ---------------------------------------------------------------------------
# Continuous vs static parity
# ---------------------------------------------------------------------------


class TestContinuousStaticParity:
    def test_shuffled_arrival_order_byte_identical(self):
        """Same request set, shuffled submission order, slot pool ≤ half the
        request count: per-request greedy outputs must be byte-identical to
        the static bucketed baseline."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(8)
        static = eng.serve_static(prompts, budgets, max_batch=4)
        for perm_seed in [1, 2]:
            order = np.random.default_rng(perm_seed).permutation(len(prompts))
            out_perm = eng.serve([prompts[i] for i in order],
                                 [budgets[i] for i in order], max_batch=4)
            for j, i in enumerate(order):
                assert out_perm[j] == static[i], f"request {i} diverged"

    def test_arrival_trace_parity(self):
        """Staggered Poisson-ish arrivals change scheduling, never outputs."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(6, seed=3)
        static = eng.serve_static(prompts, budgets, max_batch=3)
        arrivals = [0, 0, 2, 3, 3, 7]
        cont, sched = eng.serve(prompts, budgets, max_batch=3,
                                arrival_chunks=arrivals,
                                return_scheduler=True)
        assert cont == static
        assert 0.0 < sched.stats.mean_occupancy <= 1.0

    def test_pool_of_one_slot(self):
        """Degenerate pool: pure sequential serving, still identical."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=5)
        assert eng.serve(prompts, budgets, max_batch=1) == \
            eng.serve_static(prompts, budgets, max_batch=4)


# ---------------------------------------------------------------------------
# Per-row position counters vs the shared-scalar baseline
# ---------------------------------------------------------------------------


def _layer_cache(B, c=8, r=4, max_seq=32, Hkv=2, Dh=8):
    cache = init_compressed_cache(
        num_layers=1, batch=B, max_seq=max_seq, block_size=c, block_slots=r,
        num_kv_heads=Hkv, head_dim=Dh, dtype=jnp.float32)
    return {k: v[0] for k, v in cache.items() if k != "lengths"}


class TestPerRowLengthsParity:
    EF = jax.random.normal(jax.random.PRNGKey(7), (8, 4)) * 0.3

    def _roll_to(self, t_stop, kvs, backend):
        """Decode a single row (B=1) to position t_stop with scalar t —
        the shared-scalar baseline path."""
        q, k, v = kvs
        lc = _layer_cache(1)
        for t in range(t_stop):
            _, lc = compressed_decode_attention(
                q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], lc,
                self.EF, self.EF, jnp.int32(t), plan=backend)
        return lc

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_unequal_rows_match_scalar_baseline(self, backend):
        """A batched step at unequal per-row positions — one row exactly at
        the block boundary (its fold must commit), one mid-block, one past a
        completed block — must equal three independent shared-scalar (B=1)
        decodes. GQA: H=4 over Hkv=2."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        S, H, Hkv, Dh = 20, 4, 2, 8
        q = jax.random.normal(ks[0], (3, S, H, Dh))
        k = jax.random.normal(ks[1], (3, S, Hkv, Dh))
        v = jax.random.normal(ks[2], (3, S, Hkv, Dh))
        positions = [5, 7, 12]      # mid-block, boundary (c=8), block 1

        # per-row shared-scalar baselines
        row_outs, row_caches = [], []
        for b, t in enumerate(positions):
            kvs = (q[b:b + 1], k[b:b + 1], v[b:b + 1])
            lc = self._roll_to(t, kvs, backend)
            o, lc = compressed_decode_attention(
                q[b:b + 1, t:t + 1], k[b:b + 1, t:t + 1],
                v[b:b + 1, t:t + 1], lc, self.EF, self.EF, jnp.int32(t),
                plan=backend)
            row_outs.append(o)
            row_caches.append(lc)

        # batched per-row-lengths step from the assembled caches
        lc_b = {key: jnp.concatenate(
            [self._roll_to(t, (q[b:b + 1], k[b:b + 1], v[b:b + 1]),
                           backend)[key]
             for b, t in enumerate(positions)])
            for key in ("raw_k", "raw_v", "comp_k", "comp_v")}
        qs = jnp.stack([q[b, t] for b, t in enumerate(positions)])[:, None]
        kss = jnp.stack([k[b, t] for b, t in enumerate(positions)])[:, None]
        vs = jnp.stack([v[b, t] for b, t in enumerate(positions)])[:, None]
        out_b, lc_b = compressed_decode_attention(
            qs, kss, vs, lc_b, self.EF, self.EF,
            jnp.asarray(positions, jnp.int32), plan=backend)

        np.testing.assert_allclose(out_b, jnp.concatenate(row_outs),
                                   atol=1e-5)
        for key in lc_b:
            np.testing.assert_allclose(
                lc_b[key],
                jnp.concatenate([rc[key] for rc in row_caches]), atol=1e-5,
                err_msg=key)

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_scalar_broadcasts_to_vector(self, backend):
        """t given as () and as a constant (B,) vector are the same step."""
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 1, 4, 8))
        k = jax.random.normal(ks[1], (2, 1, 2, 8))
        v = jax.random.normal(ks[2], (2, 1, 2, 8))
        lc = _layer_cache(2)
        o_s, c_s = compressed_decode_attention(
            q, k, v, lc, self.EF, self.EF, jnp.int32(3), plan=backend)
        o_v, c_v = compressed_decode_attention(
            q, k, v, lc, self.EF, self.EF, jnp.full((2,), 3, jnp.int32),
            plan=backend)
        np.testing.assert_array_equal(o_s, o_v)
        for key in c_s:
            np.testing.assert_array_equal(c_s[key], c_v[key])

    def test_full_cache_unequal_rows(self):
        """Standard-attention decode with per-row t matches per-row B=1."""
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        B, S, H, Hkv, Dh = 2, 16, 4, 2, 8
        cache_k = jax.random.normal(ks[0], (B, S, Hkv, Dh))
        cache_v = jax.random.normal(ks[1], (B, S, Hkv, Dh))
        q = jax.random.normal(ks[2], (B, 1, H, Dh))
        k = jax.random.normal(ks[3], (B, 1, Hkv, Dh))
        v = jax.random.normal(ks[4], (B, 1, Hkv, Dh))
        ts = jnp.asarray([4, 11], jnp.int32)
        out_b, cb = full_decode_attention(
            q, k, v, {"k": cache_k, "v": cache_v}, ts)
        for b in range(B):
            out_1, c1 = full_decode_attention(
                q[b:b + 1], k[b:b + 1], v[b:b + 1],
                {"k": cache_k[b:b + 1], "v": cache_v[b:b + 1]},
                jnp.int32(int(ts[b])))
            np.testing.assert_allclose(out_b[b:b + 1], out_1, atol=1e-6)
            np.testing.assert_allclose(cb["k"][b:b + 1], c1["k"], atol=1e-6)


# ---------------------------------------------------------------------------
# Scheduler mechanics
# ---------------------------------------------------------------------------


class TestSchedulerMechanics:
    def test_streaming_callbacks(self):
        """on_token streams every output token in order; on_complete fires
        exactly once per request with the full output."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(5, seed=9)
        streamed = {i: [] for i in range(len(prompts))}
        completed = {}
        outs = eng.serve(prompts, budgets, max_batch=2,
                         on_token=lambda rid, tok: streamed[rid].append(tok),
                         on_complete=lambda rid, toks: completed.setdefault(
                             rid, list(toks)))
        for i, o in enumerate(outs):
            assert streamed[i] == o
            assert completed[i] == o

    def test_arrivals_respected(self):
        """A request is never admitted before its arrival chunk."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(3, seed=11)
        sched = Scheduler(eng, max_batch=2)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, tokens=tuple(p),
                                 max_new_tokens=budgets[i],
                                 arrival_chunk=[0, 0, 4][i]))
        admitted_at = {}
        orig_admit = sched.pool.admit

        def admit(row, req, cache, first):
            admitted_at[req.rid] = sched.stats.ticks
            orig_admit(row, req, cache, first)

        sched.pool.admit = admit
        sched.run()
        assert admitted_at[2] >= 4
        assert admitted_at[0] == admitted_at[1] == 0

    def test_budget_exceeding_max_seq_rejected(self):
        eng, _, _ = _engine(max_seq=32)
        with pytest.raises(ValueError, match="max_seq"):
            eng.serve([[1] * 24], max_new_tokens=16, max_batch=2)
        with pytest.raises(ValueError, match="max_seq"):
            eng.serve_static([[1] * 24], max_new_tokens=16, max_batch=2)

    def test_zero_budget_rejected(self):
        """max_new_tokens <= 0 fails fast at submission on both schedulers
        (a request that can emit nothing is a caller bug, not a no-op)."""
        eng, _, _ = _engine()
        prompts, _ = _requests(3, seed=15)
        budgets = [0, 4, 0]
        with pytest.raises(ValueError, match="request 0.*max_new_tokens"):
            eng.serve(prompts, budgets, max_batch=2)
        with pytest.raises(ValueError, match="request 0.*max_new_tokens"):
            eng.serve_static(prompts, budgets, max_batch=2)

    def test_pool_requires_per_row_lengths(self):
        """Model families with a shared scalar cache can't pool-schedule."""

        class ScalarEngine:
            def init_pool_cache(self, n):
                return {"k": jnp.zeros((1, n, 4, 2, 8)),
                        "length": jnp.zeros((), jnp.int32)}

        with pytest.raises(ValueError, match="serve_static"):
            SlotPool(ScalarEngine(), 4)

    def test_pool_owner_survives_donation(self):
        """The chunk scan donates the pool cache; the SlotPool owner swaps in
        the returned buffers, so repeated serves on one engine (and direct
        decode_tokens use in between) never touch a donated array."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=13)
        first = eng.serve(prompts, budgets, max_batch=2)
        # interleave a batch-level decode (its own donated cache)
        toks = np.asarray([prompts[0][:8], prompts[1][:8]], np.int32)
        eng.generate_batch(toks, 4)
        second, sched = eng.serve(prompts, budgets, max_batch=2,
                                  return_scheduler=True)
        assert first == second
        # the owner's cache is live (donation replaced, not invalidated)
        assert np.asarray(sched.pool.cache["lengths"]).shape == (2,)


# ---------------------------------------------------------------------------
# Preemption: evict-and-requeue with byte-identical resume
# ---------------------------------------------------------------------------


class TestPreemption:
    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_preempt_resume_byte_identical(self, backend):
        """Property test: low-priority requests running first, high-priority
        arrivals displacing them mid-stream, shuffled submission order — a
        preempted request's snapshot-restored resume must be byte-identical
        to an uninterrupted run (the static baseline), on both kernel
        backends."""
        eng, _, _ = _engine(backend=backend)
        prompts, budgets = _requests(8, seed=21)
        static = eng.serve_static(prompts, budgets, max_batch=4)
        order = list(np.random.default_rng(2).permutation(len(prompts)))
        out, sched = eng.serve(
            [prompts[i] for i in order], [budgets[i] for i in order],
            max_batch=2,
            # late arrivals are strictly more urgent: they must preempt
            priorities=[3, 3, 3, 3, 0, 0, 0, 0],
            arrival_chunks=[0, 0, 0, 0, 2, 2, 3, 3],
            return_scheduler=True)
        assert sched.stats.preemptions > 0
        for j, i in enumerate(order):
            assert out[j] == static[i], f"request {i} diverged"

    def test_one_slot_pool_preemption(self):
        """Degenerate 1-slot pool: every high-priority arrival preempts THE
        slot; the victim bounces back and forth and must still finish
        byte-identically."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=23)
        static = eng.serve_static(prompts, budgets, max_batch=4)
        out, sched = eng.serve(prompts, budgets, max_batch=1,
                               priorities=[2, 1, 1, 0],
                               arrival_chunks=[0, 1, 2, 3],
                               return_scheduler=True)
        assert sched.stats.preemptions > 0
        assert out == static

    def test_chunked_prefill_preemption(self):
        """A PREFILLING slot can be preempted mid-prompt; its snapshot
        carries the prefill progress and resumes without re-reading
        committed chunks."""
        eng, _, _ = _engine(prefill_chunk=8)
        prompts, budgets = _requests(8, seed=25)
        static = eng.serve_static(prompts, budgets, max_batch=4)
        out, sched = eng.serve(prompts, budgets, max_batch=2,
                               priorities=[3, 3, 2, 2, 1, 1, 0, 0],
                               arrival_chunks=[0, 0, 1, 1, 2, 2, 3, 3],
                               return_scheduler=True)
        assert sched.stats.preemptions > 0
        assert out == static

    def test_equal_priority_never_preempts(self):
        """Preemption needs STRICT urgency: same-priority arrivals wait for
        a free slot (no thrash between peers)."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(6, seed=27)
        out, sched = eng.serve(prompts, budgets, max_batch=2,
                               priorities=[1] * 6,
                               arrival_chunks=[0, 0, 1, 2, 3, 4],
                               return_scheduler=True)
        assert sched.stats.preemptions == 0
        assert out == eng.serve_static(prompts, budgets, max_batch=4)


# ---------------------------------------------------------------------------
# SLO scheduling: EDF ordering, bounded queue, deadlines
# ---------------------------------------------------------------------------


class TestSLOScheduling:
    def test_priority_classes_order_admission(self):
        """With one slot and simultaneous arrivals, admission follows
        priority classes (then submission order)."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=31)
        completed = []
        eng.serve(prompts, budgets, max_batch=1,
                  priorities=[2, 0, 1, 0],
                  on_complete=lambda rid, toks: completed.append(rid))
        assert completed == [1, 3, 2, 0]

    def test_edf_within_class(self):
        """Same priority: the earlier deadline runs first."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(3, seed=33)
        completed = []
        eng.serve(prompts, budgets, max_batch=1,
                  deadlines=[None, 50, 200],
                  on_complete=lambda rid, toks: completed.append(rid))
        assert completed[0] == 1          # deadline 50 beats 200 and None

    def test_bounded_queue_sheds_least_urgent(self):
        """Submissions beyond max_queue shed the least-valued entry with an
        explicit ShedResult — never silent unbounded queueing — and every
        admitted request still completes byte-identically."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(8, seed=35)
        static = eng.serve_static(prompts, budgets, max_batch=4)
        out, sched = eng.serve(prompts, budgets, max_batch=2, max_queue=3,
                               priorities=[0, 0, 1, 1, 2, 2, 2, 2],
                               return_scheduler=True)
        shed = [o for o in out if isinstance(o, ShedResult)]
        assert shed and sched.stats.sheds == len(shed)
        assert all(o.reason == "queue_full" for o in shed)
        # shedding picks the least-valued entry KNOWN AT SUBMIT TIME, so
        # later low-priority arrivals can't retroactively save an earlier
        # victim — but the most urgent class is never shed
        assert all(o.priority >= 1 for o in shed)
        for o, s in zip(out, static):
            assert isinstance(o, ShedResult) or o == s

    def test_infeasible_deadline_shed_not_admitted(self):
        """A deadline that cannot be met even by the optimistic estimate is
        shed at admission, not admitted to fail."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(2, seed=37)
        out, sched = eng.serve(prompts, budgets, max_batch=2,
                               deadlines=[None, 0],
                               return_scheduler=True)
        assert isinstance(out[1], ShedResult)
        assert out[1].reason == "deadline_infeasible"
        assert sched.stats.deadline_misses == 0

    def test_deadline_met_not_counted_missed(self):
        """Generous deadlines complete with zero misses and no sheds."""
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=39)
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               deadlines=[1000] * 4,
                               return_scheduler=True)
        assert sched.stats.deadline_misses == 0
        assert sched.stats.sheds == 0
        assert out == eng.serve_static(prompts, budgets, max_batch=4)

    def test_counters_line_mentions_every_counter(self):
        stats = Scheduler(_engine()[0], max_batch=1).stats
        line = stats.counters_line()
        for name in ("preemptions", "sheds", "deadline_misses", "retries",
                     "quarantines"):
            assert name in line
