"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't kill collection
from hypothesis import given, settings, strategies as st

from repro.core import blockwise_causal_attention, exact_linformer_attention
from repro.core.projections import effective_k, pool_weights
from repro.optim.grad_utils import dequantize_int8, quantize_int8

SET = settings(max_examples=25, deadline=None)


@SET
@given(seed=st.integers(0, 2**31 - 1),
       k=st.sampled_from([2, 4, 8, 16]))
def test_linformer_attention_is_convex_mixture(seed, k):
    """Outputs are softmax mixtures of compressed values — permutation of the
    compressed slots must not change the result."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (1, 8, 2, 4))
    kk = jax.random.normal(ks[1], (1, 8, 2, 4))
    v = jax.random.normal(ks[2], (1, 8, 2, 4))
    E = jax.random.normal(ks[3], (8, k)) * 0.5
    F = jax.random.normal(ks[4], (8, k)) * 0.5
    out = exact_linformer_attention(q, kk, v, E, F)
    perm = jax.random.permutation(ks[0], k)
    out_p = exact_linformer_attention(q, kk, v, E[:, perm], F[:, perm])
    np.testing.assert_allclose(out, out_p, atol=1e-5)


@SET
@given(seed=st.integers(0, 2**31 - 1),
       t=st.integers(1, 31))
def test_blockwise_causality_property(seed, t):
    """For ANY position t: future perturbations never change outputs < t."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, 32, 2, 4))
    k = jax.random.normal(ks[1], (1, 32, 2, 4))
    v = jax.random.normal(ks[2], (1, 32, 2, 4))
    E = jax.random.normal(ks[3], (8, 2)) * 0.5
    base = blockwise_causal_attention(q, k, v, E, E, block_size=8)
    noise = jax.random.normal(ks[0], (1, 32 - t, 2, 4)) * 5
    pert = blockwise_causal_attention(q, k.at[:, t:].add(noise),
                                      v.at[:, t:].add(noise), E, E,
                                      block_size=8)
    np.testing.assert_allclose(base[:, :t], pert[:, :t], atol=1e-5)


@SET
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3))
def test_quantize_bound_property(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    assert int(jnp.abs(q).max()) <= 127
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-9 * scale


@SET
@given(c=st.sampled_from([4, 8, 16, 32]), r_pow=st.integers(0, 2))
def test_pool_weights_partition_of_unity(c, r_pow):
    r = 2 ** r_pow
    w = pool_weights(c, r)
    np.testing.assert_allclose(np.asarray(w).sum(0), np.ones(r), atol=1e-6)
    assert np.all(np.asarray(w) >= 0)
    # each input position feeds exactly one slot
    assert np.all((np.asarray(w) > 0).sum(1) == 1)


@SET
@given(k=st.integers(2, 512), decay=st.floats(0.01, 1.0),
       L=st.integers(2, 96))
def test_effective_k_monotone_bounded(k, decay, L):
    ks = [effective_k(k, decay, i, L) for i in range(L)]
    assert ks[0] == k
    assert all(1 <= x <= k for x in ks)
    assert all(a >= b for a, b in zip(ks, ks[1:]))


@SET
@given(seed=st.integers(0, 2**31 - 1))
def test_checkpoint_flatten_roundtrip(seed):
    from repro.checkpoint.checkpointer import _flatten, _unflatten_into
    rng = np.random.default_rng(seed)
    tree = {"a": {"b": rng.normal(size=(3, 2)).astype(np.float32)},
            "c": [rng.normal(size=(4,)).astype(np.float32),
                  rng.integers(0, 5, (2,)).astype(np.int32)]}
    tree = jax.tree.map(jnp.asarray, tree)
    rt = _unflatten_into(tree, _flatten(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(rt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@SET
@given(seed=st.integers(0, 2**31 - 1),
       n_docs=st.integers(1, 12),
       seq_len=st.sampled_from([8, 16, 32]))
def test_packing_conserves_tokens(seed, n_docs, seq_len):
    """No document token is lost or duplicated by the greedy packer."""
    from repro.data.packing import pack_documents
    from repro.data.pipeline import BOS, EOS, PAD
    rng = np.random.default_rng(seed)
    docs = [rng.integers(10, 1000, rng.integers(1, 40)).astype(np.int64)
            for _ in range(n_docs)]
    out = pack_documents(docs, seq_len)
    # reconstruct the stream: all rows concatenated, first token of labels
    # appended per row to recover the trailing position
    stream = np.concatenate(
        [np.concatenate([t, l[-1:]]) for t, l in zip(out["tokens"],
                                                     out["labels"])])
    stream = stream[(stream != PAD) & (stream != BOS) & (stream != EOS)]
    expect = np.concatenate(docs)
    np.testing.assert_array_equal(np.sort(stream), np.sort(expect))


@SET
@given(seed=st.integers(0, 2**31 - 1),
       n_pages=st.integers(2, 24),
       n_ops=st.integers(1, 60))
def test_page_allocator_trace_invariants(seed, n_pages, n_ops):
    """Random admit/grow/retire/preempt/quarantine traces on the page
    allocator: no double-allocation, no leak, no cross-row aliasing, TRASH
    never handed out, all-or-nothing allocation, and every freed page is
    scrubbed (zeroed) BEFORE it can be reused. A numpy byte arena stands in
    for the device pages: rows stamp their id into owned pages, the scrub
    callback zeroes freed ones, and any aliasing or unscrubbed reuse shows
    up as foreign bytes."""
    from repro.serving.paged import PageAllocator, pages_needed
    rng = np.random.default_rng(seed)
    arena = np.full((n_pages,), -1, np.int64)        # -1 = never touched

    def scrub(pages):
        for p in pages:
            assert arena[p] != 0, f"page {p} freed while already scrubbed"
            arena[p] = 0                             # zero-before-reuse

    alloc = PageAllocator(n_pages, scrub=scrub)
    assert alloc.trash_page == n_pages - 1
    owners = {}                                       # row -> stamp
    for step in range(n_ops):
        alloc.check()
        row = int(rng.integers(0, 6))
        op = rng.choice(["alloc", "free", "free", "alloc", "alloc"])
        if op == "alloc":
            n = int(rng.integers(0, 4))
            free_before = alloc.free_pages
            pages = alloc.alloc(row, n)
            if pages is None:
                # all-or-nothing: a refused alloc changes nothing
                assert n > free_before
                assert alloc.free_pages == free_before
                continue
            assert len(pages) == n
            assert alloc.free_pages == free_before - n
            stamp = owners.setdefault(row, row * 1000 + step + 1)
            for p in pages:
                assert p != alloc.trash_page
                # a fresh page is either virgin or scrubbed — never holds
                # another row's bytes (aliasing / missing-scrub detector)
                assert arena[p] in (-1, 0), \
                    f"page {p} reused with stale bytes {arena[p]}"
                arena[p] = stamp
        else:                                         # retire/preempt/quarantine
            pages = alloc.pages_of(row)
            for p in pages:
                assert arena[p] == owners[row], "page aliased across rows"
            freed = alloc.free_row(row)
            assert freed == len(pages)
            owners.pop(row, None)
            assert all(arena[p] == 0 for p in pages)  # scrubbed on free
    alloc.check()
    # drain everything: the arena partitions back to fully free
    for row in list(alloc.owned_rows()):
        alloc.free_row(row)
    alloc.check()
    assert alloc.free_pages == alloc.usable_pages
    assert all(b in (-1, 0) for b in arena[:-1])
    assert arena[alloc.trash_page] == -1              # TRASH never touched


@SET
@given(tokens=st.integers(0, 10_000), c=st.sampled_from([4, 8, 16, 32]))
def test_pages_needed_is_exact_ceiling(tokens, c):
    """pages_needed is the exact ceiling: enough for `tokens`, and one page
    fewer is never enough (capacity planning neither starves nor pads)."""
    from repro.serving.paged import pages_needed
    n = pages_needed(tokens, c)
    assert n * c >= tokens
    assert (n - 1) * c < tokens or tokens == 0


@SET
@given(seed=st.integers(0, 2**31 - 1),
       temp=st.floats(0.5, 4.0))
def test_exact_linformer_scale_invariance_of_value_projection(seed, temp):
    """Scaling F scales outputs linearly (value path is linear)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (1, 8, 2, 4))
    k = jax.random.normal(ks[1], (1, 8, 2, 4))
    v = jax.random.normal(ks[2], (1, 8, 2, 4))
    E = jax.random.normal(ks[3], (8, 4)) * 0.5
    F = jax.random.normal(ks[4], (8, 4)) * 0.5
    o1 = exact_linformer_attention(q, k, v, E, F)
    o2 = exact_linformer_attention(q, k, v, E, F * temp)
    np.testing.assert_allclose(o2, o1 * temp, atol=1e-4, rtol=1e-4)
