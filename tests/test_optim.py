"""Optimizer: AdamW math, schedules, clipping, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    global_norm, make_schedule
from repro.optim.grad_utils import (compress_with_feedback, decompress,
                                    dequantize_int8, quantize_int8)


class TestAdamW:
    def test_matches_reference_formula(self):
        cfg = OptimizerConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                              weight_decay=0.0)
        p = {"w": jnp.array([[1.0, -2.0]]), "b": jnp.array([0.5])}
        g = {"w": jnp.array([[0.1, 0.2]]), "b": jnp.array([-0.3])}
        st = adamw_init(p, cfg)
        newp, st = adamw_update(g, st, p, cfg, jnp.asarray(0.1))
        # step 1: mhat = g, vhat = g^2  =>  delta = g/(|g|+eps) = sign(g)
        np.testing.assert_allclose(newp["w"], p["w"] - 0.1 * np.sign(g["w"]),
                                   atol=1e-5)
        np.testing.assert_allclose(newp["b"], p["b"] - 0.1 * np.sign(g["b"]),
                                   atol=1e-5)

    def test_weight_decay_applies_to_matrices_only(self):
        cfg = OptimizerConfig(lr=1.0, weight_decay=0.1)
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = jax.tree.map(jnp.zeros_like, p)
        st = adamw_init(p, cfg)
        newp, _ = adamw_update(g, st, p, cfg, jnp.asarray(1.0))
        assert float(newp["w"][0, 0]) == pytest.approx(0.9)
        assert float(newp["b"][0]) == pytest.approx(1.0)

    def test_bf16_moments(self):
        cfg = OptimizerConfig(moment_dtype="bfloat16")
        p = {"w": jnp.ones((4, 4))}
        st = adamw_init(p, cfg)
        assert st["mu"]["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((4, 4), 0.01)}
        newp, st = adamw_update(g, st, p, cfg, jnp.asarray(1e-2))
        assert bool(jnp.isfinite(newp["w"]).all())

    def test_convergence_on_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
        p = {"x": jnp.array([5.0, -3.0])}
        st = adamw_init(p, cfg)
        for _ in range(200):
            g = {"x": 2 * p["x"]}
            p, st = adamw_update(g, st, p, cfg, jnp.asarray(0.1))
        assert float(jnp.abs(p["x"]).max()) < 0.1


class TestSchedules:
    def test_warmup_then_cosine(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              schedule="cosine")
        lr = make_schedule(cfg)
        assert float(lr(0)) == 0.0
        assert float(lr(5)) == pytest.approx(0.5)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)
        assert float(lr(60)) == pytest.approx(0.5, abs=1e-6)

    def test_linear_and_constant(self):
        lin = make_schedule(OptimizerConfig(lr=2.0, warmup_steps=0,
                                            total_steps=100,
                                            schedule="linear"))
        assert float(lin(50)) == pytest.approx(1.0)
        const = make_schedule(OptimizerConfig(lr=2.0, warmup_steps=0,
                                              schedule="constant"))
        assert float(const(1000)) == pytest.approx(2.0)


class TestGradUtils:
    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 3.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(np.sqrt(90.0), rel=1e-5)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
        # under the limit -> unchanged
        g2 = {"a": jnp.full((4,), 0.01)}
        clipped2, _ = clip_by_global_norm(g2, 1.0)
        np.testing.assert_allclose(clipped2["a"], g2["a"], atol=1e-7)

    def test_quantize_roundtrip_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 7
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_is_unbiased_over_steps(self):
        """With a constant gradient, EF-compressed sum converges to true sum."""
        g = {"w": jnp.array([0.001, 0.5, -0.3])}
        res = None
        total = jnp.zeros(3)
        n = 50
        for _ in range(n):
            comp, res = compress_with_feedback(g, res)
            total = total + decompress(comp)["w"]
        np.testing.assert_allclose(total / n, g["w"], atol=2e-3)
