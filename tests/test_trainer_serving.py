"""Trainer fault tolerance + serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.models import model as M
from repro.serving import ServingEngine
from repro.serving.engine import bucket_requests
from repro.train import Trainer
from tests.conftest import f32


def _tcfg(tmp_path, **kw):
    base = dict(seq_len=32, global_batch=4, steps=10, log_every=100,
                checkpoint_every=5, checkpoint_dir=str(tmp_path),
                optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=100))
    base.update(kw)
    return TrainConfig(**base)


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        cfg = f32(get_smoke_config("qwen3-8b"))
        tr = Trainer(cfg, _tcfg(tmp_path, steps=30), log_fn=lambda s: None)
        params, opt, ds = tr.init_state()
        stream_losses = []
        from repro.data import pipeline
        stream = pipeline.batches(tr.corpus, ds, batch=4, seq=32)
        for step in range(30):
            b, ds = next(stream)
            batch = jax.tree.map(jnp.asarray, b)
            params, opt, m = tr.train_step(params, opt, batch)
            stream_losses.append(float(m["loss"]))
        assert np.mean(stream_losses[-5:]) < np.mean(stream_losses[:5]) - 0.3

    def test_preemption_checkpoint_and_resume(self, tmp_path):
        cfg = f32(get_smoke_config("qwen3-8b"))
        calls = {"n": 0}

        def preempt():
            calls["n"] += 1
            return calls["n"] == 3          # preempt at step 3

        tr = Trainer(cfg, _tcfg(tmp_path), preempt_check=preempt,
                     log_fn=lambda s: None)
        m = tr.run()
        assert m["preempted_at"] == 3
        # resume continues from the preemption checkpoint
        tr2 = Trainer(cfg, _tcfg(tmp_path), log_fn=lambda s: None)
        params, opt, ds, start = tr2.restore_or_init()
        assert start == 3
        assert ds.step == 3                 # data stream resumes exactly
        m2 = tr2.run()
        assert "preempted_at" not in m2

    def test_resume_reproduces_batch_stream(self, tmp_path):
        """No skipped/duplicated data after failover (DESIGN §6)."""
        from repro.data import DataState, SyntheticCorpus, pipeline
        c = SyntheticCorpus(512, seed=0)
        full = []
        stream = pipeline.batches(c, DataState(0, 0), batch=2, seq=16)
        for _ in range(6):
            b, st = next(stream)
            full.append(b["tokens"])
        resumed = []
        stream2 = pipeline.batches(c, DataState(0, 3), batch=2, seq=16)
        for _ in range(3):
            b, st = next(stream2)
            resumed.append(b["tokens"])
        for a, b in zip(full[3:], resumed):
            np.testing.assert_array_equal(a, b)

    def test_microbatch_accumulation_close_to_full_batch(self, tmp_path):
        cfg = f32(get_smoke_config("qwen3-8b"))
        from repro.train.trainer import make_train_step
        from repro.optim import adamw_init
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params, ocfg)
        from repro.data import DataState, SyntheticCorpus, make_causal_batch
        b = jax.tree.map(jnp.asarray, make_causal_batch(
            SyntheticCorpus(512), DataState(0, 0), batch=4, seq=32))
        full = make_train_step(cfg, ocfg)(params, opt, b)
        micro = make_train_step(cfg, ocfg, microbatch=2)(params, opt, b)
        np.testing.assert_allclose(float(full[2]["loss"]),
                                   float(micro[2]["loss"]), rtol=1e-4)
        w_f = jax.tree.leaves(full[0])[0]
        w_m = jax.tree.leaves(micro[0])[0]
        np.testing.assert_allclose(w_f, w_m, atol=5e-5)

    def test_straggler_watchdog_logs(self, tmp_path):
        cfg = f32(get_smoke_config("qwen3-8b"))
        logs = []
        tr = Trainer(cfg, _tcfg(tmp_path), log_fn=logs.append)
        tr.step_times = [0.1] * 10
        tr._watchdog(11, 0.5)
        assert any("straggler" in l for l in logs)


class TestServing:
    def _engine(self, arch="qwen3-8b", temperature=0.0):
        cfg = f32(get_smoke_config(arch))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return ServingEngine(params, cfg, max_seq=128,
                             cache_dtype=jnp.float32,
                             temperature=temperature), cfg, params

    def test_bucket_requests(self):
        prompts = [[1] * 4, [1] * 7, [2] * 4, [3] * 4, [1] * 7]
        buckets = bucket_requests(prompts, max_batch=2)
        for b in buckets:
            lens = {len(prompts[i]) for i in b}
            assert len(lens) == 1
            assert len(b) <= 2
        assert sorted(i for b in buckets for i in b) == [0, 1, 2, 3, 4]

    def test_greedy_generation_matches_manual_decode(self):
        eng, cfg, params = self._engine()
        prompt = np.array([[1, 5, 9, 2, 7, 4, 8, 3] * 2], np.int32)  # 16 = block multiple
        out = eng.generate_batch(prompt, max_new_tokens=4)
        # manual: full forward for first token, then stepwise
        logits, _, cache = M.forward(
            params, cfg, {"tokens": jnp.asarray(prompt)}, return_cache=True,
            cache_max_seq=128, cache_dtype=jnp.float32)
        cur = int(jnp.argmax(logits[:, -1], -1)[0])
        toks = [cur]
        for _ in range(3):
            lg, cache = M.decode_step(
                params, cfg, {"tokens": jnp.asarray([[cur]], jnp.int32)},
                cache)
            cur = int(jnp.argmax(lg[0, 0]))
            toks.append(cur)
        assert out[0].tolist() == toks

    def test_prefill_with_remainder_tokens(self):
        """Prompt length not a multiple of the block: remainder decodes."""
        eng, cfg, params = self._engine()
        p1 = np.array([[1, 5, 9, 2, 7, 4, 8, 3, 6, 1, 2, 3, 4, 5, 6, 7, 9, 9,
                        9]], np.int32)       # 19 tokens, block=16
        out = eng.generate_batch(p1, max_new_tokens=3)
        assert out.shape == (1, 3)

    def test_serve_mixed_lengths(self):
        eng, cfg, params = self._engine()
        prompts = [[1, 2, 3], [4, 5, 6], [1, 2, 3, 4, 5, 6, 7, 8]]
        outs = eng.serve(prompts, max_new_tokens=4, max_batch=2)
        assert len(outs) == 3
        assert all(len(o) <= 4 for o in outs)

    def test_compressed_cache_smaller_than_full(self):
        eng_lin, cfg, params = self._engine()
        cfg_std = cfg.with_attention_kind("standard")
        eng_std = ServingEngine(params, cfg_std, max_seq=128,
                                cache_dtype=jnp.float32)
        assert eng_lin.cache_bytes(4) < eng_std.cache_bytes(4)
