"""Chunked + batched admission prefill: chunk-attention parity with the
monolithic blockwise-causal form (both backends), chunked-engine vs
monolithic-engine byte parity on sampled outputs, prefill/decode
interleaving, and batched-admission mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.core.causal import (blockwise_causal_attention,
                               blockwise_causal_prefix_attention,
                               compress_blocks)
from repro.kernels import ops as kernel_ops
from repro.models import model as M
from repro.serving import ServingEngine


def _cfg(kind="linformer_causal", backend="auto", max_seq=160):
    attn = AttentionConfig(
        kind=kind,
        backend=backend,
        num_heads=4,
        num_kv_heads=2,              # GQA
        head_dim=8,
        linformer=LinformerConfig(block_size=8, block_slots=4),
    )
    return ModelConfig(name="chunked-prefill-test", num_layers=2, d_model=32,
                       vocab_size=256, max_seq_len=max_seq, attention=attn,
                       dtype="float32", remat="none")


def _engines(cfg, prefill_chunk, max_seq=160, decode_chunk=4):
    """(monolithic, chunked) engine pair sharing one set of params."""
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mk = lambda pc: ServingEngine(params, cfg, max_seq=max_seq,
                                  cache_dtype=jnp.float32,
                                  decode_chunk=decode_chunk,
                                  prefill_chunk=pc)
    return mk(0), mk(prefill_chunk)


# ---------------------------------------------------------------------------
# Attention-level parity: prefix-chunk form vs monolithic blockwise-causal
# ---------------------------------------------------------------------------


class TestPrefixAttentionParity:
    """A chunk of queries at a nonzero start offset, attending the
    slot-resident compressed cache, must reproduce the corresponding rows
    of the monolithic blockwise-causal attention."""

    def _setup(self, backend="reference", B=2, S=32, H=4, Hkv=2, Dh=8, c=8,
               r=4, M_total=40):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, S, H, Dh))
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
        E = jax.random.normal(ks[3], (c, r)) * 0.3
        F = jax.random.normal(ks[4], (c, r)) * 0.3
        # compare each backend's chunk form against ITS OWN monolithic form
        # (cross-backend differences are ~1e-7; within-backend is bitwise)
        if backend == "fused":
            full = kernel_ops.fused_blockwise_causal_attention(
                q, k, v, E, F, block_size=c, block_slots=r,
                scale=Dh ** -0.5)
        else:
            full = blockwise_causal_attention(q, k, v, E, F, block_size=c)
        nb = S // c
        kbar = compress_blocks(k.reshape(B, nb, c, Hkv, Dh), E)
        vbar = compress_blocks(v.reshape(B, nb, c, Hkv, Dh), F)
        pad = ((0, 0), (0, M_total - nb * r), (0, 0), (0, 0))
        comp_k = jnp.pad(kbar.reshape(B, nb * r, Hkv, Dh), pad)
        comp_v = jnp.pad(vbar.reshape(B, nb * r, Hkv, Dh), pad)
        return q, k, v, comp_k, comp_v, full

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_shared_offset(self, backend):
        q, k, v, ck, cv, full = self._setup(backend)
        start = jnp.full((2,), 2, jnp.int32)     # chunk = blocks [2, 4)
        args = (q[:, 16:], k[:, 16:], v[:, 16:], ck, cv, start)
        if backend == "fused":
            out = kernel_ops.fused_chunk_prefill_attention(
                *args, block_size=8, block_slots=4, scale=8 ** -0.5)
        else:
            out = blockwise_causal_prefix_attention(
                *args, block_size=8, block_slots=4)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(full[:, 16:]))

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_per_row_offsets(self, backend):
        """Rows of one batched chunk forward at DIFFERENT absolute offsets
        (the batched-admission case) each match their monolithic rows."""
        q, k, v, ck, cv, full = self._setup(backend)
        start = jnp.asarray([2, 1], jnp.int32)
        qc = jnp.stack([q[0, 16:32], q[1, 8:24]])
        kc = jnp.stack([k[0, 16:32], k[1, 8:24]])
        vc = jnp.stack([v[0, 16:32], v[1, 8:24]])
        if backend == "fused":
            out = kernel_ops.fused_chunk_prefill_attention(
                qc, kc, vc, ck, cv, start, block_size=8, block_slots=4,
                scale=8 ** -0.5)
        else:
            out = blockwise_causal_prefix_attention(
                qc, kc, vc, ck, cv, start, block_size=8, block_slots=4)
        want = jnp.stack([full[0, 16:32], full[1, 8:24]])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_zero_offset_is_monolithic(self):
        """start_blocks = 0 over the whole sequence IS the monolithic form."""
        q, k, v, ck, cv, full = self._setup()
        out = blockwise_causal_prefix_attention(
            q, k, v, ck, cv, jnp.zeros((2,), jnp.int32),
            block_size=8, block_slots=4)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(full))


# ---------------------------------------------------------------------------
# Model-level: chunked prefill_chunk calls == one monolithic prefill forward
# ---------------------------------------------------------------------------


class TestModelChunkParity:
    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_two_chunks_match_monolithic_cache(self, backend):
        """Aligned chunk shapes: prefilling 32 tokens as 2×16 must give
        bitwise the SAME compressed cache and last-token logits as the
        16-token monolithic forward extended by a 16-token chunk — and the
        full-block cache contents must match the 32-token monolithic
        forward to fp tolerance (XLA re-tiles gemms across shapes, so
        cross-shape comparisons are ~1e-7, not bitwise)."""
        cfg = _cfg(backend=backend, max_seq=64)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(
            np.random.default_rng(0).integers(4, 256, (1, 32)), jnp.int32)
        _, _, mono = M.forward(params, cfg, {"tokens": toks},
                               return_cache=True, cache_max_seq=64,
                               cache_dtype=jnp.float32)
        cache = M.init_cache(cfg, batch=1, max_seq=64, dtype=jnp.float32)
        _, cache = M.prefill_chunk(params, cfg, {"tokens": toks[:, :16]},
                                   cache, jnp.asarray([16]))
        lg, cache = M.prefill_chunk(params, cfg, {"tokens": toks[:, 16:]},
                                    cache, jnp.asarray([16]))
        assert int(cache["lengths"][0]) == 32
        np.testing.assert_allclose(np.asarray(cache["comp_k"]),
                                   np.asarray(mono["comp_k"]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(cache["comp_v"]),
                                   np.asarray(mono["comp_v"]), atol=1e-5)

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_padded_final_chunk(self, backend):
        """A final chunk with n_valid < P (prompt not a chunk multiple,
        padding fills whole blocks at the end) advances lengths by n_valid
        and leaves the VALID slot range identical to an unpadded run."""
        cfg = _cfg(backend=backend, max_seq=64)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(
            np.random.default_rng(1).integers(4, 256, (1, 24)), jnp.int32)
        cache_a = M.init_cache(cfg, batch=1, max_seq=64, dtype=jnp.float32)
        _, cache_a = M.prefill_chunk(params, cfg, {"tokens": toks[:, :16]},
                                     cache_a, jnp.asarray([16]))
        padded = jnp.zeros((1, 16), jnp.int32).at[:, :8].set(toks[:, 16:24])
        lg_a, cache_a = M.prefill_chunk(params, cfg, {"tokens": padded},
                                        cache_a, jnp.asarray([8]))
        assert int(cache_a["lengths"][0]) == 24
        # unpadded reference: same trailing 8 tokens as one exact chunk
        cache_b = M.init_cache(cfg, batch=1, max_seq=64, dtype=jnp.float32)
        _, cache_b = M.prefill_chunk(params, cfg, {"tokens": toks[:, :16]},
                                     cache_b, jnp.asarray([16]))
        lg_b, cache_b = M.prefill_chunk(params, cfg,
                                        {"tokens": toks[:, 16:24]},
                                        cache_b, jnp.asarray([8]))
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
        # 24 tokens = 3 blocks = 12 valid slots; padded junk beyond is
        # invisible (visibility is bounded by lengths) and may differ
        np.testing.assert_array_equal(
            np.asarray(cache_a["comp_k"][:, :, :12]),
            np.asarray(cache_b["comp_k"][:, :, :12]))


# ---------------------------------------------------------------------------
# Engine-level: chunked admission vs monolithic admission, byte parity
# ---------------------------------------------------------------------------


class TestChunkedEngineParity:
    # prompt lengths covering: shorter than one block (5), shorter than one
    # chunk (12), exact chunk multiple (16, 32), chunk boundary == fold
    # boundary with remainder (19, 40), long multi-chunk (61, 80)
    LENS = [5, 8, 12, 16, 19, 32, 40, 61, 80, 24]

    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_outputs_byte_identical(self, backend):
        cfg = _cfg(backend=backend)
        mono, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(0)
        prompts = [list(rng.integers(4, 256, L)) for L in self.LENS]
        budgets = [int(rng.choice([3, 6, 10])) for _ in self.LENS]
        assert mono.serve(prompts, budgets, max_batch=4) == \
            chun.serve(prompts, budgets, max_batch=4)

    def test_standard_attention_kind(self):
        cfg = _cfg(kind="standard")
        mono, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(1)
        prompts = [list(rng.integers(4, 256, L))
                   for L in [5, 16, 23, 48, 64, 33]]
        budgets = [int(rng.choice([3, 6])) for _ in prompts]
        assert mono.serve(prompts, budgets, max_batch=3) == \
            chun.serve(prompts, budgets, max_batch=3)

    def test_one_slot_pool_and_arrival_trace(self):
        cfg = _cfg()
        mono, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(2)
        prompts = [list(rng.integers(4, 256, L)) for L in [40, 8, 19, 32]]
        budgets = [4, 6, 3, 5]
        want = mono.serve(prompts, budgets, max_batch=2)
        assert chun.serve(prompts, budgets, max_batch=1) == want
        assert chun.serve(prompts, budgets, max_batch=2,
                          arrival_chunks=[0, 1, 3, 6]) == want

    def test_matches_static_baseline(self):
        cfg = _cfg()
        _, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(3)
        prompts = [list(rng.integers(4, 256, L)) for L in self.LENS]
        budgets = [int(rng.choice([2, 5, 8])) for _ in self.LENS]
        assert chun.serve(prompts, budgets, max_batch=4) == \
            chun.serve_static(prompts, budgets, max_batch=4)

    def test_streaming_and_repeat_serve(self):
        """Callbacks stream chunk-admitted requests too, and the pool owner
        survives donation across repeated serves."""
        cfg = _cfg()
        _, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(4)
        prompts = [list(rng.integers(4, 256, L)) for L in [33, 12, 48]]
        budgets = [4, 3, 5]
        streamed = {i: [] for i in range(3)}
        done = {}
        outs = chun.serve(prompts, budgets, max_batch=2,
                          on_token=lambda r, t: streamed[r].append(t),
                          on_complete=lambda r, ts: done.setdefault(
                              r, list(ts)))
        for i, o in enumerate(outs):
            assert streamed[i] == o and done[i] == o
        assert chun.serve(prompts, budgets, max_batch=2) == outs

    def test_padded_chunk_window_crossing_max_seq(self):
        """A prompt near max_seq whose padded final chunk window crosses
        max_seq must not corrupt earlier slots: without allocation slack,
        dynamic_update_slice would CLAMP the out-of-bounds write window
        down over still-valid compressed slots (regression test)."""
        cfg = _cfg(max_seq=96)
        mono, chun = _engines(cfg, prefill_chunk=64, max_seq=96)
        rng = np.random.default_rng(9)
        prompts = [list(rng.integers(4, 256, L)) for L in (90, 92, 45)]
        assert mono.serve(prompts, [4, 3, 4], max_batch=2) == \
            chun.serve(prompts, [4, 3, 4], max_batch=2)

    def test_invalid_prefill_chunk_rejected(self):
        cfg = _cfg()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        for bad in (12, 4, -8):
            with pytest.raises(ValueError, match="prefill_chunk"):
                ServingEngine(params, cfg, max_seq=160, prefill_chunk=bad)

    def test_empty_prompt_rejected(self):
        """An empty prompt must fail fast on every path — under chunked
        admission a zero-token PREFILLING slot would never activate and
        the scheduler would spin forever (regression test)."""
        cfg = _cfg()
        mono, chun = _engines(cfg, prefill_chunk=16)
        for eng in (mono, chun):
            with pytest.raises(ValueError, match="empty prompt"):
                eng.serve([[1, 2, 3], []], [4, 4], max_batch=2)
        with pytest.raises(ValueError, match="empty prompt"):
            mono.serve_static([[]], [4], max_batch=2)


# ---------------------------------------------------------------------------
# Scheduling behaviour: interleaving + batched admission
# ---------------------------------------------------------------------------


class TestChunkedScheduling:
    def test_long_prompt_does_not_stall_decode(self):
        """A long prompt prefills across many rounds while a short request
        admitted alongside it KEEPS DECODING: the short request must
        complete before the long one emits its first token — exactly the
        head-of-line blocking monolithic admission exhibits."""
        cfg = _cfg()
        _, chun = _engines(cfg, prefill_chunk=16, decode_chunk=2)
        rng = np.random.default_rng(5)
        long_p = list(rng.integers(4, 256, 80))     # 5 chunk rounds
        short_p = list(rng.integers(4, 256, 8))
        events = []
        chun.serve([long_p, short_p], [4, 4], max_batch=2,
                   on_token=lambda r, t: events.append(("tok", r)),
                   on_complete=lambda r, ts: events.append(("done", r)))
        first_long_tok = events.index(("tok", 0))
        short_done = events.index(("done", 1))
        assert short_done < first_long_tok, \
            "short request should finish while the long prompt prefills"

    def test_batched_admission_shares_forwards(self):
        """Several arrivals prefilling together must ride shared batched
        forwards: far fewer prefill launches than monolithic's one-per-
        request, with identical outputs."""
        cfg = _cfg()
        mono, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(6)
        prompts = [list(rng.integers(4, 256, 48)) for _ in range(4)]
        budgets = [3, 4, 5, 6]
        want = mono.serve(prompts, budgets, max_batch=4)
        outs, sched = chun.serve(prompts, budgets, max_batch=4,
                                 return_scheduler=True)
        assert outs == want
        # 4 requests × 48 tokens = 3 chunk rounds, each ONE batched forward
        assert sched.stats.prefill_forwards == 3
        assert sched.stats.prefill_tokens == 4 * 48

    def test_remainder_groups_batch(self):
        """Same-remainder requests share one batched remainder launch."""
        cfg = _cfg()
        mono, chun = _engines(cfg, prefill_chunk=16)
        rng = np.random.default_rng(7)
        prompts = [list(rng.integers(4, 256, 19)) for _ in range(3)]
        want = mono.serve(prompts, [3, 4, 5], max_batch=4)
        outs, sched = chun.serve(prompts, [3, 4, 5], max_batch=4,
                                 return_scheduler=True)
        assert outs == want
        # one 16-token chunk forward + one shared 3-token remainder launch
        assert sched.stats.prefill_forwards == 2

    def test_prefilling_rows_ride_decode_masked(self):
        """While a row prefills, concurrent decode chunks must not corrupt
        it: interleave short decodes with a long prefill and check the long
        request's output equals its solo (empty-pool) run."""
        cfg = _cfg()
        _, chun = _engines(cfg, prefill_chunk=16, decode_chunk=2)
        rng = np.random.default_rng(8)
        long_p = list(rng.integers(4, 256, 77))     # remainder 5 at the end
        shorts = [list(rng.integers(4, 256, 8)) for _ in range(3)]
        solo = chun.serve([long_p], [6], max_batch=2)
        mixed = chun.serve([long_p] + shorts, [6, 3, 3, 3], max_batch=2)
        assert mixed[0] == solo[0]
