"""Core Linformer (paper Eq. 7): equivalences, sharing modes, projections."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig
from repro.core import (attend_compressed, exact_linformer_attention,
                        init_linformer_params, num_projection_matrices,
                        project_kv)
from repro.core.causal import NEG_INF
from repro.core.projections import (blockwise_project, conv_as_linear,
                                    effective_k, linear_project, pool_weights)


def _qkv(B=2, S=32, H=4, Hkv=2, Dh=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, Dh)),
            jax.random.normal(ks[1], (B, S, Hkv, Dh)),
            jax.random.normal(ks[2], (B, S, Hkv, Dh)))


def _std_attention(q, k, v, causal=False):
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    qg = q.reshape(B, S, Hkv, H // Hkv, Dh)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k) / np.sqrt(Dh)
    if causal:
        s = jnp.where(jnp.tril(jnp.ones((S, S), bool)), s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgst,bthd->bshgd", p, v).reshape(B, S, H, Dh)


class TestExactForm:
    def test_identity_projection_recovers_standard_attention(self):
        q, k, v = _qkv()
        E = jnp.eye(32)
        out = exact_linformer_attention(q, k, v, E, E)
        np.testing.assert_allclose(out, _std_attention(q, k, v), atol=2e-5)

    def test_output_shape_and_linear_cost_shape(self):
        q, k, v = _qkv(S=64)
        E = jax.random.normal(jax.random.PRNGKey(9), (64, 8)) * 0.3
        kbar, vbar = project_kv(k, v, E, E)
        assert kbar.shape == (2, 8, 2, 8)           # (B, k, Hkv, Dh)
        out = exact_linformer_attention(q, k, v, E, E)
        assert out.shape == q.shape

    def test_e_rows_sliced_for_short_sequences(self):
        q, k, v = _qkv(S=16)
        E = jax.random.normal(jax.random.PRNGKey(9), (64, 8)) * 0.3
        out = exact_linformer_attention(q, k, v, E, E)
        out2 = exact_linformer_attention(q, k, v, E[:16], E[:16])
        np.testing.assert_allclose(out, out2, atol=1e-6)

    def test_key_padding_zeroed_before_compression(self):
        q, k, v = _qkv()
        E = jax.random.normal(jax.random.PRNGKey(9), (32, 8)) * 0.3
        mask = jnp.ones((2, 32), bool).at[:, 20:].set(False)
        out1 = exact_linformer_attention(q, k, v, E, E,
                                         key_padding_mask=mask)
        # zeroing the padded keys/values by hand must be identical
        keep = mask[:, :, None, None]
        out2 = exact_linformer_attention(q, k * keep, v * keep, E, E)
        np.testing.assert_allclose(out1, out2, atol=1e-6)

    def test_per_head_projection(self):
        q, k, v = _qkv()
        E = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 8)) * 0.3
        out = exact_linformer_attention(q, k, v, E, E)
        assert out.shape == q.shape
        # head 0 result must differ from shared-E result
        out_shared = exact_linformer_attention(q, k, v, E[0], E[0])
        assert not np.allclose(out, out_shared)


class TestSharing:
    def _cfg(self, sharing):
        return AttentionConfig(
            kind="linformer", num_heads=12, num_kv_heads=12, head_dim=16,
            linformer=LinformerConfig(k=8, sharing=sharing))

    @pytest.mark.parametrize("sharing,expected", [
        ("headwise", 24), ("kv", 12), ("layerwise", 1), ("none", 288)])
    def test_distinct_matrix_counts_paper_s4(self, sharing, expected):
        # paper §4: 12-layer 12-head -> headwise 24, kv 12, layerwise 1
        cfg = self._cfg(sharing)
        assert num_projection_matrices(cfg, 12) == expected

    @pytest.mark.parametrize("sharing", ["headwise", "kv", "layerwise", "none"])
    def test_init_shapes(self, sharing):
        cfg = self._cfg(sharing)
        p = init_linformer_params(jax.random.PRNGKey(0), cfg, num_layers=3,
                                  max_seq=64)
        if sharing == "layerwise":
            assert p["shared"]["E"].shape == (64, 8)
        elif sharing == "none":
            assert p["per_layer"]["E"].shape == (3, 12, 64, 8)
        else:
            assert p["per_layer"]["E"].shape == (3, 64, 8)
        if sharing == "headwise":
            assert "F" in p["per_layer"]
        if sharing == "kv":
            assert "F" not in p["per_layer"]


class TestProjections:
    def test_conv_is_blockdiagonal_linear(self):
        # paper §4 "general projections": conv(kernel=stride=c) == structured E
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 2, 8))
        W = jax.random.normal(jax.random.PRNGKey(1), (8, 2)) * 0.5
        blockwise = blockwise_project(x, W)
        E = conv_as_linear(W, 32)
        dense = linear_project(x, E)
        np.testing.assert_allclose(blockwise, dense, atol=1e-5)

    def test_pool_weights_rows_average(self):
        w = pool_weights(8, 2)
        assert w.shape == (8, 2)
        np.testing.assert_allclose(w.sum(axis=0), [1.0, 1.0], atol=1e-6)
        x = jnp.ones((1, 8, 1, 4))
        out = blockwise_project(x, w)
        np.testing.assert_allclose(out, jnp.ones((1, 2, 1, 4)), atol=1e-6)

    def test_effective_k_nonuniform(self):
        # paper §4: higher layers can use smaller k
        ks = [effective_k(128, 0.25, i, 12) for i in range(12)]
        assert ks[0] == 128
        assert ks[-1] == 32
        assert all(a >= b for a, b in zip(ks, ks[1:]))
        assert effective_k(128, 1.0, 5, 12) == 128


class TestNonuniformK:
    """Paper §4: smaller projected dimension in higher layers, end to end
    (unscanned encoder path — per-layer E shapes differ)."""

    def test_encoder_with_k_decay_runs_and_shrinks(self):
        import dataclasses
        import jax
        from repro.configs import get_smoke_config
        from repro.models import model as M

        base = get_smoke_config("linformer-paper")
        cfg = dataclasses.replace(
            base, dtype="float32", num_layers=4, scan_layers=False,
            attention=dataclasses.replace(
                base.attention,
                linformer=dataclasses.replace(base.attention.linformer,
                                              k=16, sharing="headwise",
                                              k_decay=0.25)))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        ks = [blk["attn"]["lin"]["E"].shape[-1]
              for blk in params["layers_list"]]
        assert ks[0] == 16 and ks[-1] == 4
        assert all(a >= b for a, b in zip(ks, ks[1:]))
        toks = jnp.ones((2, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((2, 32), jnp.int32)}
        loss, _ = M.loss_fn(params, cfg, batch)
        assert bool(jnp.isfinite(loss))
        g = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
        assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


class TestAttendCompressed:
    def test_kv_mask(self):
        q, k, v = _qkv()
        E = jax.random.normal(jax.random.PRNGKey(2), (32, 8)) * 0.3
        kbar, vbar = project_kv(k, v, E, E)
        mask = jnp.arange(8) < 4
        out = attend_compressed(q, kbar, vbar, kv_mask=mask)
        out2 = attend_compressed(q, kbar[:, :4], vbar[:, :4])
        np.testing.assert_allclose(out, out2, atol=1e-5)

    def test_output_in_convex_hull_of_values(self):
        q, k, v = _qkv()
        E = jax.random.normal(jax.random.PRNGKey(2), (32, 8)) * 0.3
        kbar, vbar = project_kv(k, v, E, E)
        out = attend_compressed(q, kbar, vbar)
        # softmax mixture => outputs bounded by compressed-value extremes
        hi = vbar.max(axis=1)[:, None]
        lo = vbar.min(axis=1)[:, None]
        G = q.shape[2] // vbar.shape[2]
        hi = jnp.repeat(hi, G, axis=2)
        lo = jnp.repeat(lo, G, axis=2)
        assert bool(jnp.all(out <= hi + 1e-5))
        assert bool(jnp.all(out >= lo - 1e-5))
